//! Compiled forward passes: "plan once, execute many".
//!
//! The paper's central GPU optimization is the lifecycle *around* the
//! shaders, not the shaders themselves — "reuse memory between layers"
//! and cache compiled kernels so the per-inference path does no
//! allocation and no recompilation (§GPU memory handling). This module is
//! that lifecycle for the CPU backend:
//!
//! - [`ExecutionPlan::compile`] runs shape inference over an
//!   [`Architecture`] for one batch size, computes **tensor liveness**
//!   over the layer chain, and assigns every intermediate (plus im2col
//!   scratch) to a slot in a preallocated **arena** — steady-state
//!   forward passes perform zero per-layer heap allocation.
//! - Convolution strategy is chosen **per layer** by a [`CostModel`]
//!   whose coefficients are measured on this host at first use
//!   (microbenchmark calibration), replacing the interpreter's single
//!   executor-wide [`ConvStrategy`]. The comparative-framework
//!   literature (Bahrampour et al.) shows the winning algorithm flips
//!   with layer geometry; E12 (`fig_plan`) regenerates that result.
//! - FFT convs bake their **precalculated filter spectra** into the plan
//!   (the paper's own phrase), so per-forward work is input transforms
//!   only.
//! - Weights can stay **quantized-resident** (ROADMAP item 2, "use lower
//!   resolution on floating point"): [`PlanPrecision`] bakes i8/f16
//!   weight tensors with their scales into the plan steps, the cost
//!   model picks a per-layer precision under a configurable accuracy
//!   budget in auto mode, and the integer/f16 kernels in
//!   [`super::conv`]/[`super::dense`] run straight off the resident form.
//!
//! The walk-the-architecture interpreter ([`super::CpuExecutor`]) is
//! retained as the correctness oracle: `rust/tests/plan.rs` holds the
//! planned executor bit-exact against it for every layer kind and every
//! ladder batch size under f32, and within the documented per-precision
//! tolerances (`testutil::assert_within_tolerance`) for quantized plans.

use super::fft::Complex;
use super::fft_conv::{FftConvPlan, FftScratch};
use super::parallel::{resolve_intra_threads, KernelPool, Par};
use super::{
    avg_pool2d_into, conv1d_into, conv2d_direct_f16_par_into, conv2d_direct_i8_par_into,
    conv2d_direct_i8i8_into, conv2d_direct_i8i8_par_into, conv2d_direct_into,
    conv2d_direct_par_into, conv2d_im2col_f16_par_into, conv2d_im2col_i8_par_into,
    conv2d_im2col_i8i8_par_into, conv2d_im2col_into, conv2d_im2col_par_into, dense_f16_par_into,
    dense_i8_par_into, dense_i8i8_par_into, dense_par_into, fft_conv_flops, gemm_i8_i32,
    global_avg_pool_into, max_pool1d_into, max_pool2d_into, relu_in_place, softmax_in_place,
    Conv1dParams, Conv2dParams, ConvStrategy, LayerTiming, PackedI8, Pool2dParams, MAX_GEMM_K,
};
use crate::compression::{quantize_i8_into, symmetric_i8_scale, ResidentF16, ResidentI8};
use crate::model::{Architecture, LayerKind, WeightStore};
use crate::tensor::{DType, Shape, Tensor};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Auto strategy selection declines FFT once the plan-resident filter
/// spectra would exceed this (the paper targets memory-tight devices;
/// a forced `Fixed(Fft)` is still honored).
const FFT_SPECTRA_CAP_BYTES: usize = 16 << 20;

// ---------------------------------------------------------------------------
// Cost model
// ---------------------------------------------------------------------------

/// Per-step intra-op parallelism decision, compiled into the plan by
/// [`CostModel::parallelism`]. `threads == 1` means the step runs serial
/// on the execute thread; otherwise the kernel's partition axis is split
/// into `grain`-sized chunks across a [`KernelPool`]. The partition is a
/// pure function of `(units, threads)` — never of load or timing — so a
/// plan executes bitwise identically at any thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Worker lanes the step fans out over (1 = serial).
    pub threads: usize,
    /// Partition-axis units per chunk (`ceil(units / threads)`).
    pub grain: usize,
}

impl Parallelism {
    /// The serial decision (what every step gets at `--intra-threads 1`).
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1, grain: 0 }
    }
}

impl Default for Parallelism {
    fn default() -> Parallelism {
        Parallelism::serial()
    }
}

/// Per-operation cost coefficients (microseconds per unit of work). The
/// absolute values only matter relative to each other — the plan uses
/// them to rank conv strategies per layer geometry and to estimate whole
/// forward passes for the selector's latency-budget filter.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// µs per MAC for the direct 7-loop convolution.
    pub direct_us_per_mac: f64,
    /// µs per MAC for GEMM inner loops (im2col conv, dense).
    pub gemm_us_per_mac: f64,
    /// µs per patch-matrix element for the im2col lowering.
    pub lower_us_per_elem: f64,
    /// µs per modeled FLOP of the FFT path ([`fft_conv_flops`]).
    pub fft_us_per_flop: f64,
    /// µs per element for elementwise / pooling traffic.
    pub elem_us: f64,
    /// µs per MAC for the packed i8×i8→i32 GEMM (full-integer im2col
    /// conv and dense). Integer adds reassociate, so this inner loop
    /// autovectorizes where the f32 one cannot — measured well below
    /// [`CostModel::gemm_us_per_mac`] on every probed host.
    pub gemm_i8_us_per_mac: f64,
    /// µs per MAC for the full-integer direct convolution.
    pub direct_i8_us_per_mac: f64,
    /// µs per element for the activation-quantization boundary (one
    /// max-abs scan plus one round/clamp store per input element).
    pub quant_us_per_elem: f64,
    /// µs of fork-join overhead per parallel kernel dispatch (publish
    /// the job, wake the pool, join the barrier). Steps whose predicted
    /// parallel saving does not clear this stay serial.
    pub fork_join_us: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel::analytic()
    }
}

/// Bytes of plan-resident filter spectra an FFT conv of this geometry
/// would hold (`oc*c` planes on the power-of-two padded grid).
fn fft_spectra_bytes(c: usize, h: usize, w: usize, oc: usize, params: Conv2dParams) -> usize {
    let grid =
        (h + 2 * params.pad).next_power_of_two() * (w + 2 * params.pad).next_power_of_two();
    oc * c * grid * std::mem::size_of::<Complex>()
}

/// Partition-axis units a conv2d kernel of this strategy exposes to the
/// worker pool: direct convs split `(batch, out_channel)` output planes,
/// im2col convs split output channels (lowering and GEMM both), FFT has
/// no parallel form and stays serial.
fn conv_partition_units(s: ConvStrategy, n: usize, oc: usize) -> usize {
    match s {
        ConvStrategy::Direct => n * oc,
        ConvStrategy::Im2col => oc,
        ConvStrategy::Fft => 1,
    }
}

/// Minimum-of-N wall time for one closure, in µs.
fn probe_us(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    best
}

impl CostModel {
    /// Analytic fallback coefficients (order-of-magnitude CPU figures).
    /// Used when calibration cannot run or produces degenerate fits.
    pub fn analytic() -> CostModel {
        CostModel {
            direct_us_per_mac: 1.5e-3,
            gemm_us_per_mac: 4.0e-4,
            lower_us_per_elem: 1.5e-3,
            fft_us_per_flop: 4.0e-4,
            elem_us: 5.0e-4,
            gemm_i8_us_per_mac: 1.5e-4,
            direct_i8_us_per_mac: 7.5e-4,
            quant_us_per_elem: 5.0e-4,
            fork_join_us: 15.0,
        }
    }

    /// Calibrate the coefficients on this host with a few small
    /// microbenchmarks (a handful of milliseconds, total). Two im2col
    /// probes with different output-channel counts separate the GEMM
    /// coefficient from the patch-lowering coefficient.
    pub fn measured() -> CostModel {
        let fallback = CostModel::analytic();
        let p = Conv2dParams::new(1, 1);
        let (c, hw, k) = (8usize, 12usize, 3usize);
        let x = Tensor::randn(Shape::nchw(1, c, hw, hw), 11, 1.0);

        // Direct.
        let w8 = Tensor::randn(&[8, c, k, k][..], 12, 0.2);
        let mut out8 = Tensor::zeros(Shape::nchw(1, 8, hw, hw));
        let t_direct = probe_us(3, || {
            conv2d_direct_into(&x, &w8, None, p, &mut out8).unwrap();
        });
        let macs8 = (8 * hw * hw * c * k * k) as f64;
        let direct = t_direct / macs8;

        // im2col: two probes, solve for (gemm, lower).
        let patch_elems = (c * k * k * hw * hw) as f64;
        let mut patches = Tensor::zeros(&[c * k * k, hw * hw][..]);
        let w16 = Tensor::randn(&[16, c, k, k][..], 13, 0.2);
        let mut out16 = Tensor::zeros(Shape::nchw(1, 16, hw, hw));
        let t16 = probe_us(3, || {
            conv2d_im2col_into(&x, &w16, None, p, &mut patches, &mut out16).unwrap();
        });
        let w1 = Tensor::randn(&[1, c, k, k][..], 14, 0.2);
        let mut out1 = Tensor::zeros(Shape::nchw(1, 1, hw, hw));
        let t1 = probe_us(3, || {
            conv2d_im2col_into(&x, &w1, None, p, &mut patches, &mut out1).unwrap();
        });
        let (macs16, macs1) = ((16 * hw * hw * c * k * k) as f64, (hw * hw * c * k * k) as f64);
        // The lowering coefficient is only meaningful relative to a sane
        // GEMM fit; if noise made the GEMM slope degenerate, reject both
        // (NaN fails the ok() guard below) rather than pricing im2col
        // from garbage.
        let gemm = (t16 - t1) / (macs16 - macs1);
        let lower = if gemm.is_finite() && gemm > 0.0 {
            (t1 - gemm * macs1) / patch_elems
        } else {
            f64::NAN
        };

        // FFT.
        let pf = Conv2dParams::new(1, 2);
        let kf = 5usize;
        let wf = Tensor::randn(&[4, 4, kf, kf][..], 15, 0.2);
        let xf = Tensor::randn(Shape::nchw(1, 4, hw, hw), 16, 1.0);
        let t_fft = match FftConvPlan::new(&wf, hw, hw, pf) {
            Ok(plan) => {
                let mut scratch = plan.scratch();
                let mut outf = Tensor::zeros(Shape::nchw(1, 4, hw, hw));
                probe_us(3, || {
                    plan.run_into(&xf, None, &mut scratch, &mut outf).unwrap();
                })
            }
            Err(_) => f64::NAN,
        };
        let fft = t_fft / fft_conv_flops(1, 4, hw, hw, 4, kf, pf.pad) as f64;

        // Elementwise.
        let mut buf = Tensor::randn(&[1 << 14][..], 17, 1.0);
        let t_elem = probe_us(3, || relu_in_place(&mut buf));
        let elem = t_elem / (1 << 14) as f64;

        // Packed i8×i8→i32 GEMM (full-integer im2col conv and dense).
        let (gm, gn, gk) = (16usize, 64usize, 256usize);
        let a8 = vec![3i8; gm * gk];
        let bt8 = vec![-5i8; gn * gk];
        let mut acc8 = vec![0i32; gm * gn];
        let t_gemm_i8 = probe_us(3, || gemm_i8_i32(gm, gn, gk, &a8, &bt8, &mut acc8));
        let gemm_i8 = t_gemm_i8 / (gm * gn * gk) as f64;

        // Full-integer direct conv (same geometry as the f32 direct
        // probe, so the two coefficients are directly comparable).
        let q8 = PackedI8::pack(&ResidentI8::quantize(&w8));
        let mut xq8 = vec![0i8; x.numel()];
        let mut out8q = Tensor::zeros(Shape::nchw(1, 8, hw, hw));
        let t_direct_i8 = probe_us(3, || {
            conv2d_direct_i8i8_into(&x, &q8, None, p, &mut xq8, &mut out8q).unwrap();
        });
        let direct_i8 = t_direct_i8 / macs8;

        // Activation quantization: max-abs scan + round/clamp store.
        let qdata = buf.data().to_vec();
        let mut qcodes = vec![0i8; qdata.len()];
        let t_quant = probe_us(3, || {
            let s = symmetric_i8_scale(&qdata);
            quantize_i8_into(&qdata, s, &mut qcodes);
        });
        let quant = t_quant / qdata.len() as f64;

        // Fork-join dispatch: round-trip an empty two-chunk job through a
        // throwaway two-lane pool. This is the per-dispatch overhead a
        // parallel step must amortize, measured on this host's actual
        // wake/join latency.
        let fork_join = {
            let pool = KernelPool::new(2);
            let par = Par::new(&pool, 2);
            probe_us(8, || par.run_chunks(2, |_, _| {}))
        };

        let ok = |v: f64| v.is_finite() && v > 0.0;
        CostModel {
            direct_us_per_mac: if ok(direct) { direct } else { fallback.direct_us_per_mac },
            gemm_us_per_mac: if ok(gemm) { gemm } else { fallback.gemm_us_per_mac },
            lower_us_per_elem: if ok(lower) { lower } else { fallback.lower_us_per_elem },
            fft_us_per_flop: if ok(fft) { fft } else { fallback.fft_us_per_flop },
            elem_us: if ok(elem) { elem } else { fallback.elem_us },
            gemm_i8_us_per_mac: if ok(gemm_i8) { gemm_i8 } else { fallback.gemm_i8_us_per_mac },
            direct_i8_us_per_mac: if ok(direct_i8) {
                direct_i8
            } else {
                fallback.direct_i8_us_per_mac
            },
            quant_us_per_elem: if ok(quant) { quant } else { fallback.quant_us_per_elem },
            fork_join_us: if ok(fork_join) { fork_join } else { fallback.fork_join_us },
        }
    }

    /// The process-wide calibrated model (measured once, on first use).
    pub fn global() -> CostModel {
        static CALIBRATED: OnceLock<CostModel> = OnceLock::new();
        *CALIBRATED.get_or_init(CostModel::measured)
    }

    /// Predicted cost of one conv2d call, in µs.
    pub fn conv2d_us(
        &self,
        strategy: ConvStrategy,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        oc: usize,
        k: usize,
        params: Conv2dParams,
    ) -> crate::Result<f64> {
        let (oh, ow) = params.out_hw(h, w, k)?;
        let macs = (n * oc * oh * ow * c * k * k) as f64;
        Ok(match strategy {
            ConvStrategy::Direct => macs * self.direct_us_per_mac,
            ConvStrategy::Im2col => {
                macs * self.gemm_us_per_mac
                    + (n * c * k * k * oh * ow) as f64 * self.lower_us_per_elem
            }
            ConvStrategy::Fft => {
                fft_conv_flops(n, c, h, w, oc, k, params.pad) as f64 * self.fft_us_per_flop
            }
        })
    }

    /// Predicted cost of one *full-integer* conv2d call, in µs: the
    /// integer-path MAC coefficients plus the per-forward activation
    /// quantization of the input. FFT has no integer form, so it prices
    /// as infinite and is never picked for a full-integer layer.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_i8_us(
        &self,
        strategy: ConvStrategy,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        oc: usize,
        k: usize,
        params: Conv2dParams,
    ) -> crate::Result<f64> {
        let (oh, ow) = params.out_hw(h, w, k)?;
        let macs = (n * oc * oh * ow * c * k * k) as f64;
        let quant = (n * c * h * w) as f64 * self.quant_us_per_elem;
        Ok(match strategy {
            ConvStrategy::Direct => macs * self.direct_i8_us_per_mac + quant,
            ConvStrategy::Im2col => {
                macs * self.gemm_i8_us_per_mac
                    + (n * c * k * k * oh * ow) as f64 * self.lower_us_per_elem
                    + quant
            }
            ConvStrategy::Fft => f64::INFINITY,
        })
    }

    /// The cheapest strategy for one conv2d geometry, with its predicted
    /// cost (ties break toward direct, then im2col — deterministic).
    pub fn pick_conv2d(
        &self,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        oc: usize,
        k: usize,
        params: Conv2dParams,
    ) -> crate::Result<(ConvStrategy, f64)> {
        let mut best: Option<(ConvStrategy, f64)> = None;
        for s in [ConvStrategy::Direct, ConvStrategy::Im2col, ConvStrategy::Fft] {
            let us = self.conv2d_us(s, n, c, h, w, oc, k, params)?;
            if best.map_or(true, |(_, b)| us < b) {
                best = Some((s, us));
            }
        }
        Ok(best.unwrap())
    }

    /// [`CostModel::pick_conv2d`] under the plan's resident-memory
    /// guard: when the cheapest strategy is FFT but its plan-resident
    /// filter spectra would exceed the spectra cap (16 MB), fall back
    /// to the cheaper of direct/im2col. This is the selection
    /// [`ExecutionPlan::compile`] actually uses in auto mode, and the
    /// one [`CostModel::estimate_forward_us`] prices — so the selector's
    /// budget filter and the compiled plan agree.
    pub fn pick_conv2d_capped(
        &self,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        oc: usize,
        k: usize,
        params: Conv2dParams,
    ) -> crate::Result<(ConvStrategy, f64)> {
        self.pick_conv2d_capped_par(n, c, h, w, oc, k, params, 1)
    }

    /// [`CostModel::pick_conv2d_capped`] with the candidate costs
    /// adjusted for intra-op parallelism at `threads` lanes: each
    /// strategy is priced at its own partition granularity (direct
    /// splits `n*oc` output planes, im2col `oc` output channels, FFT
    /// stays serial), so a geometry where im2col wins serially can
    /// honestly lose to direct once direct's finer partition amortizes
    /// the fork-join overhead — and vice versa. At `threads == 1` this
    /// is exactly the serial pick.
    #[allow(clippy::too_many_arguments)]
    pub fn pick_conv2d_capped_par(
        &self,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        oc: usize,
        k: usize,
        params: Conv2dParams,
        threads: usize,
    ) -> crate::Result<(ConvStrategy, f64)> {
        let adj = |serial: f64, s: ConvStrategy| {
            let par = self.parallelism(serial, conv_partition_units(s, n, oc), threads);
            self.parallel_us(serial, par)
        };
        let mut best: Option<(ConvStrategy, f64)> = None;
        for s in [ConvStrategy::Direct, ConvStrategy::Im2col, ConvStrategy::Fft] {
            let us = adj(self.conv2d_us(s, n, c, h, w, oc, k, params)?, s);
            if best.map_or(true, |(_, b)| us < b) {
                best = Some((s, us));
            }
        }
        let (s, est) = best.unwrap();
        if s == ConvStrategy::Fft && fft_spectra_bytes(c, h, w, oc, params) > FFT_SPECTRA_CAP_BYTES
        {
            let d = adj(self.conv2d_us(ConvStrategy::Direct, n, c, h, w, oc, k, params)?, ConvStrategy::Direct);
            let i2 = adj(self.conv2d_us(ConvStrategy::Im2col, n, c, h, w, oc, k, params)?, ConvStrategy::Im2col);
            return Ok(if d <= i2 {
                (ConvStrategy::Direct, d)
            } else {
                (ConvStrategy::Im2col, i2)
            });
        }
        Ok((s, est))
    }

    /// The per-step parallelism decision: split `units` partition-axis
    /// units across up to `max_threads` lanes, but only when the
    /// predicted saving (`est_us * (1 - 1/t)`) clears twice the measured
    /// fork-join overhead — tiny layers stay serial rather than paying a
    /// dispatch that costs more than it saves.
    pub fn parallelism(&self, est_us: f64, units: usize, max_threads: usize) -> Parallelism {
        let t = max_threads.min(units).max(1);
        if t <= 1 || est_us * (1.0 - 1.0 / t as f64) <= 2.0 * self.fork_join_us {
            return Parallelism::serial();
        }
        Parallelism { threads: t, grain: units.div_ceil(t) }
    }

    /// Predicted wall time of a step under a parallelism decision:
    /// perfect speedup on the partitioned work plus one fork-join.
    pub fn parallel_us(&self, est_us: f64, par: Parallelism) -> f64 {
        if par.threads <= 1 {
            est_us
        } else {
            est_us / par.threads as f64 + self.fork_join_us
        }
    }

    /// Predicted forward-pass cost for a whole architecture at `batch`,
    /// in µs, assuming the per-layer strategy the plan would pick (the
    /// capped auto selection). This is what the model selector's
    /// latency-budget filter consumes
    /// ([`crate::selector::Candidate::for_arch`]).
    pub fn estimate_forward_us(&self, arch: &Architecture, batch: usize) -> crate::Result<f64> {
        self.estimate_forward_us_par(arch, batch, 1)
    }

    /// [`CostModel::estimate_forward_us`] at an intra-op thread count:
    /// each parallelizable layer is priced at the parallelism decision
    /// the compiled plan would take for it ([`CostModel::parallelism`]),
    /// so the selector's latency-budget filter sees the same speedup the
    /// pool actually delivers. `threads == 1` is the serial estimate.
    pub fn estimate_forward_us_par(
        &self,
        arch: &Architecture,
        batch: usize,
        threads: usize,
    ) -> crate::Result<f64> {
        let shapes = arch.shapes()?;
        let mut total = 0.0;
        for (i, layer) in arch.layers.iter().enumerate() {
            let inp = &shapes[i];
            let out = &shapes[i + 1];
            let out_elems = (batch * out.iter().product::<usize>()) as f64;
            total += match &layer.kind {
                LayerKind::Conv2d { out_ch, k, stride, pad } => {
                    let p = Conv2dParams::new(*stride, *pad);
                    self.pick_conv2d_capped_par(batch, inp[0], inp[1], inp[2], *out_ch, *k, p, threads)?
                        .1
                }
                LayerKind::Conv1d { out_ch, k, .. } => {
                    (batch * out_ch * out[1] * inp[0] * k) as f64 * self.direct_us_per_mac
                }
                LayerKind::Dense { out: of } => {
                    let serial =
                        (batch * of * inp.iter().product::<usize>()) as f64 * self.gemm_us_per_mac;
                    let par = self.parallelism(serial, *of, threads);
                    self.parallel_us(serial, par)
                }
                LayerKind::MaxPool2d { k, .. } | LayerKind::AvgPool2d { k, .. } => {
                    out_elems * (k * k) as f64 * self.elem_us
                }
                LayerKind::MaxPool1d { k, .. } => out_elems * *k as f64 * self.elem_us,
                LayerKind::GlobalAvgPool => (batch * inp.iter().product::<usize>()) as f64 * self.elem_us,
                LayerKind::Relu => out_elems * self.elem_us,
                LayerKind::Softmax => out_elems * 4.0 * self.elem_us,
                LayerKind::Flatten | LayerKind::Dropout { .. } => 0.0,
            };
        }
        Ok(total)
    }

    /// Pick the resident precision for one weight tensor under a
    /// relative-RMS quantization-error budget. Candidates whose
    /// *measured* error on these exact weights fits the budget are
    /// ranked by estimated per-MAC latency first (i8 now runs the
    /// packed full-integer GEMM, priced by its own measured
    /// coefficient), then by resident bytes — so the pick is
    /// latency-aware, with footprint breaking ties (f16 decodes through
    /// the same f32 inner loops, so it wins over f32 on bytes alone).
    pub fn pick_precision(&self, w: &Tensor, budget: f64) -> DType {
        if !(budget > 0.0) {
            return DType::F32;
        }
        let mut best = (self.gemm_us_per_mac, DType::F32.size(), DType::F32);
        for (us_per_mac, dtype) in [
            (self.gemm_us_per_mac, DType::F16),
            (self.gemm_i8_us_per_mac, DType::I8),
        ] {
            let fits = match dtype {
                DType::F16 => ResidentF16::quantize(w).relative_rms_error(w.data()) <= budget,
                _ => ResidentI8::quantize(w).relative_rms_error(w.data()) <= budget,
            };
            if fits
                && (us_per_mac < best.0 || (us_per_mac == best.0 && dtype.size() < best.1))
            {
                best = (us_per_mac, dtype.size(), dtype);
            }
        }
        best.2
    }
}

// ---------------------------------------------------------------------------
// Plan options
// ---------------------------------------------------------------------------

/// Conv-strategy policy for a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PlanStrategy {
    /// Pick per layer with the calibrated cost model (the default).
    #[default]
    Auto,
    /// Force one strategy for every conv2d (the old executor-wide knob,
    /// kept for sweeps and for bit-exact oracle comparisons).
    Fixed(ConvStrategy),
}

impl PlanStrategy {
    /// Parse a CLI value: `auto`, `direct`, `im2col` or `fft`.
    pub fn parse(s: &str) -> crate::Result<PlanStrategy> {
        Ok(match s {
            "auto" => PlanStrategy::Auto,
            "direct" => PlanStrategy::Fixed(ConvStrategy::Direct),
            "im2col" => PlanStrategy::Fixed(ConvStrategy::Im2col),
            "fft" => PlanStrategy::Fixed(ConvStrategy::Fft),
            other => anyhow::bail!(
                "unknown conv strategy `{other}` (expected auto, direct, im2col or fft)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanStrategy::Auto => "auto",
            PlanStrategy::Fixed(s) => s.name(),
        }
    }
}

/// Weight-residency precision policy for a plan (ROADMAP item 2). The
/// default keeps every weight f32 — fetched from the shared store at
/// execute time, bit-exact with the interpreter oracle. The quantized
/// policies bake reduced-precision copies (with their scales) into the
/// plan steps for conv2d direct/im2col and dense layers; FFT convs (whose
/// resident form is f32 spectra) and conv1d stay full-precision.
///
/// `Int8` runs the *full-integer* path: weights pre-packed into GEMM
/// panels, activations quantized at each such step's boundary, and one
/// i8×i8→i32 GEMM per layer with a fused requantization epilogue.
/// `Int8Weights` keeps the original weights-only form — i8-resident
/// weights dequantized on the fly inside f32 kernels — for callers that
/// want the footprint win without activation quantization error.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PlanPrecision {
    /// Full-precision everywhere (the bit-exact default).
    #[default]
    F32,
    /// f16-resident weights for every quantizable layer.
    F16,
    /// Full-integer execution: packed-i8 weights *and* quantized
    /// activations for every quantizable layer.
    Int8,
    /// Symmetric-i8-resident weights only; activations stay f32 and the
    /// kernels dequantize per element.
    Int8Weights,
    /// Per-layer pick by the cost model under
    /// [`PlanOptions::accuracy_budget`]: latency-ranked among the
    /// resident forms whose measured quantization error fits the budget
    /// (an i8 pick runs the full-integer path).
    Auto,
}

impl PlanPrecision {
    /// Parse a CLI value: `f32`, `f16`, `int8`, `int8-weights` or `auto`.
    pub fn parse(s: &str) -> crate::Result<PlanPrecision> {
        Ok(match s {
            "f32" => PlanPrecision::F32,
            "f16" => PlanPrecision::F16,
            "int8" => PlanPrecision::Int8,
            "int8-weights" => PlanPrecision::Int8Weights,
            "auto" => PlanPrecision::Auto,
            other => anyhow::bail!(
                "unknown precision `{other}` (expected f32, f16, int8, int8-weights or auto)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanPrecision::F32 => "f32",
            PlanPrecision::F16 => "f16",
            PlanPrecision::Int8 => "int8",
            PlanPrecision::Int8Weights => "int8-weights",
            PlanPrecision::Auto => "auto",
        }
    }

    /// Whether this policy replaces eligible conv2d weights with a
    /// quantized resident form (auto decides per layer, so it counts).
    fn quantizes(self) -> bool {
        !matches!(self, PlanPrecision::F32)
    }

    /// Placement-estimate bytes per parameter before a model's plans
    /// exist (the pool peeks only the manifest). Conservative for `Auto`,
    /// which may quantize everything or nothing; the estimate is replaced
    /// by the plan's actual resident bytes right after the load.
    pub fn estimate_bytes_per_param(self) -> usize {
        match self {
            PlanPrecision::F32 | PlanPrecision::Auto => 4,
            PlanPrecision::F16 => 2,
            PlanPrecision::Int8 | PlanPrecision::Int8Weights => 1,
        }
    }
}

/// Default relative-RMS weight-quantization error budget for
/// [`PlanPrecision::Auto`]. Symmetric i8 on a Gaussian-ish tensor
/// measures ≈0.6–0.9% (the per-tensor max sets the step size), so the
/// default admits i8 only for tame dynamic ranges and otherwise settles
/// on f16 (≈0.05%); raise the budget (e.g. to 0.01) to push typical
/// layers down to i8, lower it toward 0 to force f32.
pub const DEFAULT_ACCURACY_BUDGET: f64 = 0.005;

/// Options for [`ExecutionPlan::compile`].
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    pub strategy: PlanStrategy,
    /// Weight-residency precision policy.
    pub precision: PlanPrecision,
    /// Per-layer accuracy budget consumed by [`PlanPrecision::Auto`]
    /// (relative RMS weight error; see [`DEFAULT_ACCURACY_BUDGET`]).
    pub accuracy_budget: f64,
    /// Cost model override; `None` uses the process-wide calibrated one.
    pub cost_model: Option<CostModel>,
    /// Intra-op worker lanes available to each forward pass. `0` (the
    /// default) resolves through [`resolve_intra_threads`]: the
    /// `DLK_INTRA_THREADS` env var if set, else 1 (serial). Values are
    /// a *ceiling* — the per-step [`Parallelism`] decision still keeps
    /// steps serial when the fork-join overhead would not amortize.
    pub intra_threads: usize,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions {
            strategy: PlanStrategy::default(),
            precision: PlanPrecision::default(),
            accuracy_budget: DEFAULT_ACCURACY_BUDGET,
            cost_model: None,
            intra_threads: 0,
        }
    }
}

impl PlanOptions {
    /// Force one conv strategy everywhere.
    pub fn fixed(strategy: ConvStrategy) -> PlanOptions {
        PlanOptions { strategy: PlanStrategy::Fixed(strategy), ..PlanOptions::default() }
    }

    /// Default options under one precision policy.
    pub fn with_precision(precision: PlanPrecision) -> PlanOptions {
        PlanOptions { precision, ..PlanOptions::default() }
    }

    fn resolve_cost(&self) -> CostModel {
        self.cost_model.unwrap_or_else(CostModel::global)
    }
}

// ---------------------------------------------------------------------------
// Plan structure
// ---------------------------------------------------------------------------

enum Op {
    Conv2dDirect { params: Conv2dParams },
    Conv2dIm2col { params: Conv2dParams, scratch_slot: usize, patch_shape: Shape },
    /// Full-integer variants: quantize the step's input activations,
    /// run i8×i8→i32 against the packed resident panels, requantize in
    /// the epilogue. Their scratch lives in the shared integer arena
    /// ([`QuantBuffers`]), not in the f32 slots — the im2col form needs
    /// no f32 patch slot at all.
    Conv2dDirectI8 { params: Conv2dParams },
    Conv2dIm2colI8 { params: Conv2dParams },
    /// Shared across every ladder batch size's plan: the filter spectra
    /// depend only on (weights, input H×W, params), never on batch, so
    /// `PlannedExecutor` compiles them once per conv layer.
    Conv2dFft { fft: Arc<FftConvPlan> },
    Conv1d { params: Conv1dParams },
    Relu,
    MaxPool2d { params: Pool2dParams },
    AvgPool2d { params: Pool2dParams },
    MaxPool1d { k: usize, stride: usize },
    GlobalAvgPool,
    Dense,
    DenseI8,
    FlattenAlias,
    DropoutNoop,
    SoftmaxInPlace,
}

impl Op {
    fn strategy(&self) -> Option<ConvStrategy> {
        match self {
            Op::Conv2dDirect { .. } | Op::Conv2dDirectI8 { .. } => Some(ConvStrategy::Direct),
            Op::Conv2dIm2col { .. } | Op::Conv2dIm2colI8 { .. } => Some(ConvStrategy::Im2col),
            Op::Conv2dFft { .. } => Some(ConvStrategy::Fft),
            _ => None,
        }
    }

    fn in_place(&self) -> bool {
        matches!(
            self,
            Op::Relu | Op::FlattenAlias | Op::DropoutNoop | Op::SoftmaxInPlace
        )
    }

    /// Whether this step runs the full-integer path (quantized
    /// activations + packed-i8 GEMM + requantization).
    fn full_integer(&self) -> bool {
        matches!(
            self,
            Op::Conv2dDirectI8 { .. } | Op::Conv2dIm2colI8 { .. } | Op::DenseI8
        )
    }
}

/// A weight tensor quantized at compile time and kept resident in the
/// plan. Batch-independent (like FFT spectra), so `PlannedExecutor`
/// shares one `Arc` per layer across every ladder plan.
enum ResidentWeights {
    F16(ResidentF16),
    I8(ResidentI8),
    /// i8 codes pre-packed into zero-padded GEMM panels for the
    /// full-integer kernels.
    I8Packed(PackedI8),
}

impl ResidentWeights {
    fn dtype(&self) -> DType {
        match self {
            ResidentWeights::F16(_) => DType::F16,
            ResidentWeights::I8(_) | ResidentWeights::I8Packed(_) => DType::I8,
        }
    }

    fn bytes(&self) -> usize {
        match self {
            ResidentWeights::F16(r) => r.bytes(),
            ResidentWeights::I8(r) => r.bytes(),
            ResidentWeights::I8Packed(p) => p.bytes(),
        }
    }
}

struct Step {
    op: Op,
    in_slot: usize,
    out_slot: usize,
    /// Output shape, batch dimension included.
    out_shape: Shape,
    w_key: Option<String>,
    b_key: Option<String>,
    /// Quantized weight residency; `None` means f32 weights fetched from
    /// the shared store at execute time.
    resident: Option<Arc<ResidentWeights>>,
    /// Bytes of parameters this step keeps resident: the weight at its
    /// resident dtype plus the f32 bias. Zero for unweighted steps.
    param_bytes: usize,
    /// Interned layer name (shared with every `LayerTiming` this step
    /// emits — no per-forward string allocation).
    name: Arc<str>,
    kind: &'static str,
    /// Batch-scaled multiply-accumulates.
    macs: u64,
    /// Cost-model estimate, µs (parallelism-adjusted).
    est_us: f64,
    /// Compiled intra-op parallelism decision for this step.
    par: Parallelism,
}

impl Step {
    /// Resident dtype of this step's weights (`None` for unweighted steps).
    fn weight_dtype(&self) -> Option<DType> {
        self.w_key.as_ref().map(|_| {
            self.resident.as_ref().map_or(DType::F32, |r| r.dtype())
        })
    }
}

/// Liveness record for one arena buffer: which steps it spans and the
/// slot it was assigned to. Inclusive interval; two buffers may share a
/// slot only if their `[birth, death]` intervals are disjoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufferInfo {
    pub slot: usize,
    pub birth: usize,
    pub death: usize,
    pub numel: usize,
}

/// One step of the plan, as seen by introspection (tests, `dlk plan`).
#[derive(Clone, Debug)]
pub struct StepInfo {
    pub name: Arc<str>,
    pub kind: &'static str,
    pub in_slot: usize,
    pub out_slot: usize,
    pub scratch_slot: Option<usize>,
    pub in_place: bool,
    pub strategy: Option<ConvStrategy>,
    /// Resident dtype of this step's weights; `None` for unweighted steps.
    pub precision: Option<DType>,
    pub out_shape: Vec<usize>,
    pub macs: u64,
    pub est_us: f64,
    /// Whether this step runs the full-integer path (quantized
    /// activations, packed-i8 GEMM, requantization epilogue).
    pub full_integer: bool,
    /// Compiled intra-op parallelism decision (threads = 1 is serial).
    pub par: Parallelism,
}

/// Sizing for the integer scratch shared by every full-integer step:
/// max over steps of (quantized-input i8 elems, transposed-patch i8
/// elems, i32 accumulator elems). One set serves the whole plan because
/// steps run sequentially — exactly like the f32 slot arena.
#[derive(Clone, Copy, Debug)]
struct QuantSpec {
    x: usize,
    patches: usize,
    acc: usize,
}

fn grow_quant(spec: &mut Option<QuantSpec>, x: usize, patches: usize, acc: usize) {
    let s = spec.get_or_insert(QuantSpec { x: 0, patches: 0, acc: 0 });
    s.x = s.x.max(x);
    s.patches = s.patches.max(patches);
    s.acc = s.acc.max(acc);
}

/// Lazily-built integer scratch backing [`QuantSpec`].
struct QuantBuffers {
    x: Vec<i8>,
    patches: Vec<i8>,
    acc: Vec<i32>,
}

struct ArenaBuffers {
    slots: Vec<Tensor>,
    fft: Option<FftScratch>,
    quant: Option<QuantBuffers>,
}

/// A forward pass compiled for one `(architecture, batch)` pair: layer
/// sequence resolved to `_into` kernel calls over arena slots, conv
/// strategies fixed per layer, FFT filter spectra precomputed. Compile
/// once at model-load time, execute many times; the arena is allocated
/// lazily on first execute and reused forever after.
///
/// `execute` takes `&self`; concurrent callers serialize on the internal
/// arena lock (each engine shard owns its models, so in the serving
/// stack the lock is uncontended).
pub struct ExecutionPlan {
    arch_name: String,
    batch: usize,
    input_shape: Shape,
    output_shape: Shape,
    input_slot: usize,
    output_slot: usize,
    steps: Vec<Step>,
    slot_numel: Vec<usize>,
    buffers_meta: Vec<BufferInfo>,
    /// `(grid, channel_planes)` FFT scratch sizing, when any conv chose FFT.
    fft_scratch_spec: Option<(usize, usize)>,
    /// Integer scratch sizing, when any step runs full-integer.
    quant_scratch_spec: Option<QuantSpec>,
    est_us: f64,
    /// Resolved intra-op lane ceiling the plan was compiled for.
    intra_threads: usize,
    arena: Mutex<Option<ArenaBuffers>>,
    arena_builds: AtomicU64,
}

fn take_slot(slots: &mut [Tensor], i: usize) -> Tensor {
    std::mem::replace(&mut slots[i], Tensor::zeros(&[0][..]))
}

impl ExecutionPlan {
    /// Compile `arch` + `weights` for one batch size.
    pub fn compile(
        arch: &Architecture,
        weights: &WeightStore,
        batch: usize,
        opts: &PlanOptions,
    ) -> crate::Result<ExecutionPlan> {
        ExecutionPlan::compile_with_caches(
            arch,
            weights,
            batch,
            opts,
            &mut BTreeMap::new(),
            &mut BTreeMap::new(),
        )
    }

    /// [`ExecutionPlan::compile`] reusing precomputed FFT filter spectra
    /// and quantized resident weights across plans: both depend only on
    /// (weights, layer geometry), never on batch, so `PlannedExecutor`
    /// hands every ladder compile the same caches (keyed by weight name)
    /// and a conv layer's filters are transformed — and its weights
    /// quantized — exactly once per model.
    fn compile_with_caches(
        arch: &Architecture,
        weights: &WeightStore,
        batch: usize,
        opts: &PlanOptions,
        fft_cache: &mut BTreeMap<String, Arc<FftConvPlan>>,
        quant_cache: &mut BTreeMap<String, Arc<ResidentWeights>>,
    ) -> crate::Result<ExecutionPlan> {
        anyhow::ensure!(batch > 0, "plan batch must be positive");
        weights.validate(arch)?;
        let shapes = arch.shapes()?;
        let cost = opts.resolve_cost();
        let intra = resolve_intra_threads(opts.intra_threads);

        // Liveness values: index 0 is the staged input; each out-of-place
        // step births a new value (plus, for im2col, a same-step scratch
        // value). In-place steps extend the current value's lifetime.
        struct BufVal {
            birth: usize,
            death: usize,
            numel: usize,
        }
        let input_numel = batch * shapes[0].iter().product::<usize>();
        let mut bufs = vec![BufVal { birth: 0, death: 0, numel: input_numel }];
        let mut cur = 0usize;

        // Built with slot fields holding *buffer* indices; remapped to
        // arena slots after liveness assignment below.
        let mut steps: Vec<Step> = Vec::with_capacity(arch.layers.len());
        let mut fft_spec: Option<(usize, usize)> = None;
        let mut quant_spec: Option<QuantSpec> = None;

        for (i, layer) in arch.layers.iter().enumerate() {
            let inp = &shapes[i];
            let out = &shapes[i + 1];
            let out_numel = batch * out.iter().product::<usize>();
            let mut out_shape_dims = vec![batch];
            out_shape_dims.extend_from_slice(out);
            let out_shape = Shape::new(&out_shape_dims);
            let name: Arc<str> = Arc::from(layer.name.as_str());
            let kind = layer.kind.type_name();
            let w_key = format!("{}.w", layer.name);
            let b_key = format!("{}.b", layer.name);

            // MACs, batch-scaled (same accounting as the interpreter).
            let macs = match &layer.kind {
                LayerKind::Conv2d { out_ch, k, .. } => {
                    (out_ch * out[1] * out[2] * inp[0] * k * k) as u64
                }
                LayerKind::Conv1d { out_ch, k, .. } => (out_ch * out[1] * inp[0] * k) as u64,
                LayerKind::Dense { out: of } => (of * inp.iter().product::<usize>()) as u64,
                _ => 0,
            } * batch as u64;

            // In-place steps keep the current buffer; out-of-place steps
            // close it at `i` and birth a fresh one.
            let in_buf = cur;
            bufs[cur].death = i;
            let out_of_place = |bufs: &mut Vec<BufVal>, numel: usize| {
                bufs.push(BufVal { birth: i, death: i, numel });
                bufs.len() - 1
            };

            // Resident-precision selection, resolved *before* the op is
            // built: the chosen form decides the kernel family. A packed
            // full-integer resident compiles to the i8×i8 ops, which draw
            // integer scratch from the shared quant arena instead of an
            // f32 patch slot. Only direct/im2col conv and dense have
            // quantized variants; FFT convs keep f32 spectra (any
            // resident is dropped again below) and conv1d stays f32. The
            // quantized form is batch-independent, so it is shared across
            // ladder plans via `quant_cache` exactly like FFT spectra.
            let maybe_quant = matches!(
                &layer.kind,
                LayerKind::Conv2d { .. } | LayerKind::Dense { .. }
            ) && opts.precision.quantizes()
                // A forced-FFT plan never runs a quantized conv kernel;
                // skip the build so the cache stays clean.
                && !(matches!(&layer.kind, LayerKind::Conv2d { .. })
                    && matches!(opts.strategy, PlanStrategy::Fixed(ConvStrategy::Fft)));
            let mut resident: Option<Arc<ResidentWeights>> = if maybe_quant {
                if let Some(r) = quant_cache.get(&w_key) {
                    Some(r.clone())
                } else {
                    let wt = weights.get(&w_key)?;
                    let target = match opts.precision {
                        PlanPrecision::F16 => DType::F16,
                        PlanPrecision::Int8 | PlanPrecision::Int8Weights => DType::I8,
                        PlanPrecision::Auto => cost.pick_precision(wt, opts.accuracy_budget),
                        PlanPrecision::F32 => DType::F32,
                    };
                    let built = match target {
                        DType::F32 => None,
                        DType::F16 => {
                            Some(Arc::new(ResidentWeights::F16(ResidentF16::quantize(wt))))
                        }
                        DType::I8 => {
                            let q = ResidentI8::quantize(wt);
                            // `int8` (and an auto pick of i8) runs
                            // full-integer: pack the GEMM panels now.
                            // `int8-weights` — or a reduction depth that
                            // would overflow the i32 accumulator — keeps
                            // the weights-only dequantizing form.
                            let rows = q.dims()[0].max(1);
                            let k_depth = q.numel() / rows;
                            let packable =
                                !matches!(opts.precision, PlanPrecision::Int8Weights)
                                    && k_depth.next_multiple_of(4) <= MAX_GEMM_K;
                            Some(Arc::new(if packable {
                                ResidentWeights::I8Packed(PackedI8::pack(&q))
                            } else {
                                ResidentWeights::I8(q)
                            }))
                        }
                    };
                    if let Some(r) = &built {
                        quant_cache.insert(w_key.clone(), r.clone());
                    }
                    built
                }
            } else {
                None
            };
            let full_int = matches!(resident.as_deref(), Some(ResidentWeights::I8Packed(_)));

            let (op, est_us, weighted, out_buf) = match &layer.kind {
                LayerKind::Conv2d { out_ch, k, stride, pad } => {
                    let params = Conv2dParams::new(*stride, *pad);
                    let (c, h, w) = (inp[0], inp[1], inp[2]);
                    let force_quant = matches!(
                        opts.precision,
                        PlanPrecision::F16 | PlanPrecision::Int8 | PlanPrecision::Int8Weights
                    );
                    // Full-integer layers price with the integer-path
                    // coefficients (packed GEMM + activation quantization).
                    let conv_est = |s: ConvStrategy| -> crate::Result<f64> {
                        if full_int && s != ConvStrategy::Fft {
                            cost.conv2d_i8_us(s, batch, c, h, w, *out_ch, *k, params)
                        } else {
                            cost.conv2d_us(s, batch, c, h, w, *out_ch, *k, params)
                        }
                    };
                    // Auto selection compares *parallelism-adjusted*
                    // costs — each strategy priced at its own partition
                    // granularity — so the pick stays honest under
                    // intra-op threading. The tuple keeps the winner's
                    // serial estimate; the shared post-selection code
                    // below compiles it into the step's `Parallelism`
                    // decision and adjusted `est_us`.
                    let par_adj = |s: ConvStrategy, serial: f64| {
                        let units = conv_partition_units(s, batch, *out_ch);
                        cost.parallel_us(serial, cost.parallelism(serial, units, intra))
                    };
                    let (strategy, est) = match opts.strategy {
                        PlanStrategy::Fixed(s) => (s, conv_est(s)?),
                        // Forced quantization restricts auto strategy to
                        // the quantizable kernels (FFT's resident form is
                        // f32 spectra, which would silently undo the
                        // requested precision).
                        PlanStrategy::Auto if force_quant => {
                            let d = conv_est(ConvStrategy::Direct)?;
                            let i2 = conv_est(ConvStrategy::Im2col)?;
                            if par_adj(ConvStrategy::Direct, d)
                                <= par_adj(ConvStrategy::Im2col, i2)
                            {
                                (ConvStrategy::Direct, d)
                            } else {
                                (ConvStrategy::Im2col, i2)
                            }
                        }
                        // The capped pick: auto mode declines FFT when the
                        // plan-resident spectra would outgrow the cap.
                        // (Auto *precision* keeps the f32-cost strategy
                        // pick; a full-integer layer reprices its choice.)
                        PlanStrategy::Auto => {
                            let (s, _) = cost.pick_conv2d_capped_par(
                                batch, c, h, w, *out_ch, *k, params, intra,
                            )?;
                            (s, conv_est(s)?)
                        }
                    };
                    let out_buf = out_of_place(&mut bufs, out_numel);
                    let in_elems = batch * c * h * w;
                    let op = match strategy {
                        ConvStrategy::Direct if full_int => {
                            grow_quant(&mut quant_spec, in_elems, 0, 0);
                            Op::Conv2dDirectI8 { params }
                        }
                        ConvStrategy::Direct => Op::Conv2dDirect { params },
                        ConvStrategy::Im2col if full_int => {
                            let (oh, ow) = params.out_hw(h, w, *k)?;
                            let cols = oh * ow;
                            let k_pad = (c * k * k).next_multiple_of(4);
                            grow_quant(&mut quant_spec, in_elems, cols * k_pad, *out_ch * cols);
                            Op::Conv2dIm2colI8 { params }
                        }
                        ConvStrategy::Im2col => {
                            let (oh, ow) = params.out_hw(h, w, *k)?;
                            let patch_shape = Shape::new(&[c * k * k, oh * ow]);
                            let scratch = out_of_place(&mut bufs, patch_shape.numel());
                            Op::Conv2dIm2col { params, scratch_slot: scratch, patch_shape }
                        }
                        ConvStrategy::Fft => {
                            let fft = match fft_cache.get(&w_key) {
                                Some(p) => p.clone(),
                                None => {
                                    let wt = weights.get(&w_key)?;
                                    let p = Arc::new(FftConvPlan::new(wt, h, w, params)?);
                                    fft_cache.insert(w_key.clone(), p.clone());
                                    p
                                }
                            };
                            let (grid, chan) = fft.scratch_needs();
                            fft_spec = Some(match fft_spec {
                                Some((g, c0)) => (g.max(grid), c0.max(chan)),
                                None => (grid, chan),
                            });
                            Op::Conv2dFft { fft }
                        }
                    };
                    (op, est, true, out_buf)
                }
                LayerKind::Conv1d { stride, pad, .. } => {
                    let params = Conv1dParams { stride: *stride, pad: *pad };
                    let est = macs as f64 * cost.direct_us_per_mac;
                    (Op::Conv1d { params }, est, true, out_of_place(&mut bufs, out_numel))
                }
                LayerKind::Relu => (Op::Relu, out_numel as f64 * cost.elem_us, false, cur),
                LayerKind::MaxPool2d { k, stride, pad } => {
                    let params = Pool2dParams::new(*k, *stride, *pad);
                    let est = out_numel as f64 * (k * k) as f64 * cost.elem_us;
                    (Op::MaxPool2d { params }, est, false, out_of_place(&mut bufs, out_numel))
                }
                LayerKind::AvgPool2d { k, stride, pad } => {
                    let params = Pool2dParams::new(*k, *stride, *pad);
                    let est = out_numel as f64 * (k * k) as f64 * cost.elem_us;
                    (Op::AvgPool2d { params }, est, false, out_of_place(&mut bufs, out_numel))
                }
                LayerKind::MaxPool1d { k, stride } => {
                    let est = out_numel as f64 * *k as f64 * cost.elem_us;
                    (
                        Op::MaxPool1d { k: *k, stride: *stride },
                        est,
                        false,
                        out_of_place(&mut bufs, out_numel),
                    )
                }
                LayerKind::GlobalAvgPool => {
                    let est = (batch * inp.iter().product::<usize>()) as f64 * cost.elem_us;
                    (Op::GlobalAvgPool, est, false, out_of_place(&mut bufs, out_numel))
                }
                LayerKind::Dense { .. } => {
                    anyhow::ensure!(
                        inp.len() == 1,
                        "layer `{}`: dense expects a flattened input, got {inp:?}",
                        layer.name
                    );
                    if full_int {
                        let in_f = inp[0];
                        let k_pad = in_f.next_multiple_of(4);
                        grow_quant(&mut quant_spec, batch * k_pad, 0, out_numel);
                        let est = macs as f64 * cost.gemm_i8_us_per_mac
                            + (batch * in_f) as f64 * cost.quant_us_per_elem;
                        (Op::DenseI8, est, true, out_of_place(&mut bufs, out_numel))
                    } else {
                        let est = macs as f64 * cost.gemm_us_per_mac;
                        (Op::Dense, est, true, out_of_place(&mut bufs, out_numel))
                    }
                }
                LayerKind::Flatten => (Op::FlattenAlias, 0.0, false, cur),
                LayerKind::Dropout { .. } => (Op::DropoutNoop, 0.0, false, cur),
                LayerKind::Softmax => {
                    (Op::SoftmaxInPlace, out_numel as f64 * 4.0 * cost.elem_us, false, cur)
                }
            };
            // FFT convs keep f32 spectra; drop any resident picked above
            // (auto strategy may have chosen FFT after an auto-precision
            // build — the cached copy stays for other ladder batches).
            if matches!(&op, Op::Conv2dFft { .. }) {
                resident = None;
            }
            // Compile the step's parallelism decision from its op's
            // partition axis: direct convs split `(batch, out_ch)` output
            // planes, im2col convs split output channels, dense splits
            // output features (full-integer dense splits GEMM row
            // panels, i.e. the batch). Ops without a partitioned kernel
            // (pools, elementwise, FFT, conv1d) stay serial.
            let par_units = match &op {
                Op::Conv2dDirect { .. } | Op::Conv2dDirectI8 { .. } => {
                    out_shape.dim(0) * out_shape.dim(1)
                }
                Op::Conv2dIm2col { .. } | Op::Conv2dIm2colI8 { .. } => out_shape.dim(1),
                Op::Dense => out_shape.dim(1),
                Op::DenseI8 => out_shape.dim(0),
                _ => 1,
            };
            let par = cost.parallelism(est_us, par_units, intra);
            let est_us = cost.parallel_us(est_us, par);
            // Bytes the step's parameters keep resident: weights at their
            // resident dtype, biases always f32. FFT spectra are charged as
            // f32 weights — the spectra themselves vary with the calibrated
            // strategy choice, which would make byte accounting host-dependent.
            let param_bytes = if weighted {
                let w_numel = weights.get(&w_key)?.numel();
                let b_numel = weights.get(&b_key)?.numel();
                resident.as_ref().map_or(w_numel * 4, |r| r.bytes()) + b_numel * 4
            } else {
                0
            };
            steps.push(Step {
                op,
                in_slot: in_buf,
                out_slot: out_buf,
                out_shape,
                w_key: if weighted { Some(w_key) } else { None },
                b_key: if weighted { Some(b_key) } else { None },
                name,
                kind,
                macs,
                est_us,
                resident,
                param_bytes,
                par,
            });
            cur = out_buf;
        }

        // First-fit slot assignment over the (birth-ordered) liveness
        // intervals: a slot may be reused once its previous occupant's
        // inclusive interval has ended.
        let mut slot_numel: Vec<usize> = Vec::new();
        let mut slot_busy_until: Vec<usize> = Vec::new();
        let mut buffers_meta: Vec<BufferInfo> = Vec::with_capacity(bufs.len());
        for b in &bufs {
            let mut assigned = None;
            for s in 0..slot_numel.len() {
                if slot_busy_until[s] < b.birth {
                    assigned = Some(s);
                    break;
                }
            }
            let slot = match assigned {
                Some(s) => {
                    slot_numel[s] = slot_numel[s].max(b.numel);
                    slot_busy_until[s] = b.death;
                    s
                }
                None => {
                    slot_numel.push(b.numel);
                    slot_busy_until.push(b.death);
                    slot_numel.len() - 1
                }
            };
            buffers_meta.push(BufferInfo { slot, birth: b.birth, death: b.death, numel: b.numel });
        }

        // Remap the steps' buffer indices to their assigned arena slots.
        for step in &mut steps {
            step.in_slot = buffers_meta[step.in_slot].slot;
            step.out_slot = buffers_meta[step.out_slot].slot;
            if let Op::Conv2dIm2col { scratch_slot, .. } = &mut step.op {
                *scratch_slot = buffers_meta[*scratch_slot].slot;
            }
        }

        let mut input_shape_dims = vec![batch];
        input_shape_dims.extend_from_slice(&shapes[0]);
        let mut output_shape_dims = vec![batch];
        output_shape_dims.extend_from_slice(shapes.last().unwrap());
        let est_us = steps.iter().map(|s| s.est_us).sum();

        Ok(ExecutionPlan {
            arch_name: arch.name.clone(),
            batch,
            input_shape: Shape::new(&input_shape_dims),
            output_shape: Shape::new(&output_shape_dims),
            input_slot: buffers_meta[0].slot,
            output_slot: buffers_meta[cur].slot,
            steps,
            slot_numel,
            buffers_meta,
            fft_scratch_spec: fft_spec,
            quant_scratch_spec: quant_spec,
            est_us,
            intra_threads: intra,
            arena: Mutex::new(None),
            arena_builds: AtomicU64::new(0),
        })
    }

    // ---- execution --------------------------------------------------------

    /// Run the planned forward pass. Bit-exact with the interpreter
    /// oracle when both use the same conv strategy per layer.
    pub fn execute(&self, weights: &WeightStore, input: &Tensor) -> crate::Result<Tensor> {
        self.execute_inner(weights, input, None, None)
    }

    /// [`ExecutionPlan::execute`] fanning parallel steps out over a
    /// [`KernelPool`]. With `None` (or a pool when every step compiled
    /// serial) this is exactly `execute` — and because partitions are
    /// size-deterministic and writes ordered, the output is **bitwise
    /// identical** either way.
    pub fn execute_with_pool(
        &self,
        weights: &WeightStore,
        input: &Tensor,
        pool: Option<&KernelPool>,
    ) -> crate::Result<Tensor> {
        self.execute_inner(weights, input, None, pool)
    }

    /// Run the planned forward pass, recording per-layer wall time. The
    /// `LayerTiming` names are the plan's interned `Arc<str>`s — no
    /// per-call string allocation.
    pub fn execute_timed(
        &self,
        weights: &WeightStore,
        input: &Tensor,
    ) -> crate::Result<(Tensor, Vec<LayerTiming>)> {
        self.execute_timed_with_pool(weights, input, None)
    }

    /// [`ExecutionPlan::execute_timed`] over an optional [`KernelPool`].
    pub fn execute_timed_with_pool(
        &self,
        weights: &WeightStore,
        input: &Tensor,
        pool: Option<&KernelPool>,
    ) -> crate::Result<(Tensor, Vec<LayerTiming>)> {
        let mut timings = Vec::with_capacity(self.steps.len());
        let out = self.execute_inner(weights, input, Some(&mut timings), pool)?;
        Ok((out, timings))
    }

    fn execute_inner(
        &self,
        weights: &WeightStore,
        input: &Tensor,
        mut timings: Option<&mut Vec<LayerTiming>>,
        pool: Option<&KernelPool>,
    ) -> crate::Result<Tensor> {
        anyhow::ensure!(
            input.shape() == &self.input_shape,
            "plan for `{}` expects input {}, got {}",
            self.arch_name,
            self.input_shape,
            input.shape()
        );
        let mut guard = self.arena.lock().unwrap();
        if guard.is_none() {
            *guard = Some(ArenaBuffers {
                slots: self.slot_numel.iter().map(|&n| Tensor::with_capacity(n)).collect(),
                fft: self.fft_scratch_spec.map(|(g, c)| FftScratch::with_sizes(g, c)),
                quant: self.quant_scratch_spec.map(|s| QuantBuffers {
                    x: vec![0; s.x],
                    patches: vec![0; s.patches],
                    acc: vec![0; s.acc],
                }),
            });
            self.arena_builds.fetch_add(1, Ordering::Relaxed);
        }
        let ArenaBuffers { slots, fft, quant } = guard.as_mut().unwrap();

        // Stage the input into its slot (copy, not clone: no allocation).
        slots[self.input_slot].reshape_within(self.input_shape.clone())?;
        slots[self.input_slot].data_mut().copy_from_slice(input.data());

        for step in &self.steps {
            let t0 = Instant::now();
            // The compiled decision only fans out when the caller
            // actually supplied a pool; otherwise every step runs
            // serial — with bitwise-identical results either way.
            let par = match pool {
                Some(p) if step.par.threads > 1 => Par::new(p, step.par.threads),
                _ => Par::serial(),
            };
            match &step.op {
                Op::Relu => relu_in_place(&mut slots[step.in_slot]),
                Op::SoftmaxInPlace => softmax_in_place(&mut slots[step.in_slot])?,
                Op::FlattenAlias => slots[step.in_slot].reshape_within(step.out_shape.clone())?,
                Op::DropoutNoop => {}
                Op::Conv2dDirect { params } => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        let x = &slots[step.in_slot];
                        match step.resident.as_deref() {
                            None => {
                                let w = weights.get(step.w_key.as_deref().unwrap())?;
                                conv2d_direct_par_into(x, w, Some(b), *params, &mut out, par)
                            }
                            Some(ResidentWeights::F16(h)) => {
                                conv2d_direct_f16_par_into(x, h, Some(b), *params, &mut out, par)
                            }
                            Some(ResidentWeights::I8(q)) => {
                                conv2d_direct_i8_par_into(x, q, Some(b), *params, &mut out, par)
                            }
                            Some(ResidentWeights::I8Packed(_)) => anyhow::bail!(
                                "packed weights on a non-integer conv step `{}`",
                                step.name
                            ),
                        }
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Conv2dIm2col { params, scratch_slot, patch_shape } => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let mut out = take_slot(slots, step.out_slot);
                    let mut patches = take_slot(slots, *scratch_slot);
                    let r = out
                        .reshape_within(step.out_shape.clone())
                        .and_then(|_| patches.reshape_within(patch_shape.clone()))
                        .and_then(|_| {
                            let x = &slots[step.in_slot];
                            match step.resident.as_deref() {
                                None => {
                                    let w = weights.get(step.w_key.as_deref().unwrap())?;
                                    conv2d_im2col_par_into(
                                        x, w, Some(b), *params, &mut patches, &mut out, par,
                                    )
                                }
                                Some(ResidentWeights::F16(h)) => conv2d_im2col_f16_par_into(
                                    x, h, Some(b), *params, &mut patches, &mut out, par,
                                ),
                                Some(ResidentWeights::I8(q)) => conv2d_im2col_i8_par_into(
                                    x, q, Some(b), *params, &mut patches, &mut out, par,
                                ),
                                Some(ResidentWeights::I8Packed(_)) => anyhow::bail!(
                                    "packed weights on a non-integer conv step `{}`",
                                    step.name
                                ),
                            }
                        });
                    slots[*scratch_slot] = patches;
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Conv2dDirectI8 { params } => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let qb = quant.as_mut().expect("quant scratch allocated with the arena");
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        let x = &slots[step.in_slot];
                        match step.resident.as_deref() {
                            Some(ResidentWeights::I8Packed(p)) => conv2d_direct_i8i8_par_into(
                                x, p, Some(b), *params, &mut qb.x, &mut out, par,
                            ),
                            _ => anyhow::bail!(
                                "full-integer conv step `{}` lost its packed weights",
                                step.name
                            ),
                        }
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Conv2dIm2colI8 { params } => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let qb = quant.as_mut().expect("quant scratch allocated with the arena");
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        let x = &slots[step.in_slot];
                        match step.resident.as_deref() {
                            Some(ResidentWeights::I8Packed(p)) => conv2d_im2col_i8i8_par_into(
                                x,
                                p,
                                Some(b),
                                *params,
                                &mut qb.x,
                                &mut qb.patches,
                                &mut qb.acc,
                                &mut out,
                                par,
                            ),
                            _ => anyhow::bail!(
                                "full-integer conv step `{}` lost its packed weights",
                                step.name
                            ),
                        }
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Conv2dFft { fft: conv } => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let scratch = fft.as_mut().expect("fft scratch allocated with the arena");
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        conv.run_into(&slots[step.in_slot], Some(b), scratch, &mut out)
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Conv1d { params } => {
                    let w = weights.get(step.w_key.as_deref().unwrap())?;
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        conv1d_into(&slots[step.in_slot], w, Some(b), *params, &mut out)
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::MaxPool2d { params } => {
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out
                        .reshape_within(step.out_shape.clone())
                        .and_then(|_| max_pool2d_into(&slots[step.in_slot], *params, &mut out));
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::AvgPool2d { params } => {
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out
                        .reshape_within(step.out_shape.clone())
                        .and_then(|_| avg_pool2d_into(&slots[step.in_slot], *params, &mut out));
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::MaxPool1d { k, stride } => {
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        max_pool1d_into(&slots[step.in_slot], *k, *stride, &mut out)
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::GlobalAvgPool => {
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out
                        .reshape_within(step.out_shape.clone())
                        .and_then(|_| global_avg_pool_into(&slots[step.in_slot], &mut out));
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::Dense => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        let x = &slots[step.in_slot];
                        match step.resident.as_deref() {
                            None => {
                                let w = weights.get(step.w_key.as_deref().unwrap())?;
                                dense_par_into(x, w, Some(b), &mut out, par)
                            }
                            Some(ResidentWeights::F16(h)) => {
                                dense_f16_par_into(x, h, Some(b), &mut out, par)
                            }
                            Some(ResidentWeights::I8(q)) => {
                                dense_i8_par_into(x, q, Some(b), &mut out, par)
                            }
                            Some(ResidentWeights::I8Packed(_)) => anyhow::bail!(
                                "packed weights on a non-integer dense step `{}`",
                                step.name
                            ),
                        }
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
                Op::DenseI8 => {
                    let b = weights.get(step.b_key.as_deref().unwrap())?;
                    let qb = quant.as_mut().expect("quant scratch allocated with the arena");
                    let mut out = take_slot(slots, step.out_slot);
                    let r = out.reshape_within(step.out_shape.clone()).and_then(|_| {
                        let x = &slots[step.in_slot];
                        match step.resident.as_deref() {
                            Some(ResidentWeights::I8Packed(p)) => dense_i8i8_par_into(
                                x, p, Some(b), &mut qb.x, &mut qb.acc, &mut out, par,
                            ),
                            _ => anyhow::bail!(
                                "full-integer dense step `{}` lost its packed weights",
                                step.name
                            ),
                        }
                    });
                    slots[step.out_slot] = out;
                    r?;
                }
            }
            if let Some(ts) = timings.as_deref_mut() {
                ts.push(LayerTiming {
                    name: step.name.clone(),
                    kind: step.kind,
                    micros: t0.elapsed().as_secs_f64() * 1e6,
                    macs: step.macs,
                });
            }
        }

        // The only per-forward allocation: the caller-owned output.
        let out = &slots[self.output_slot];
        debug_assert_eq!(out.shape(), &self.output_shape);
        Tensor::new(self.output_shape.clone(), out.data().to_vec())
    }

    // ---- introspection ----------------------------------------------------

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Expected input shape, batch dimension included.
    pub fn input_shape(&self) -> &Shape {
        &self.input_shape
    }

    /// Output shape, batch dimension included.
    pub fn output_shape(&self) -> &Shape {
        &self.output_shape
    }

    /// Cost-model estimate for one forward pass, µs.
    pub fn estimated_us(&self) -> f64 {
        self.est_us
    }

    /// Arena slot capacities, in elements.
    pub fn slot_sizes(&self) -> &[usize] {
        &self.slot_numel
    }

    /// Peak arena footprint: every slot at capacity, in bytes.
    pub fn peak_arena_bytes(&self) -> usize {
        self.slot_numel.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }

    /// Liveness + slot assignment per buffer (arena-aliasing tests).
    pub fn buffers(&self) -> &[BufferInfo] {
        &self.buffers_meta
    }

    /// Per-step view: slots, strategy, estimates.
    pub fn steps(&self) -> Vec<StepInfo> {
        self.steps
            .iter()
            .map(|s| StepInfo {
                name: s.name.clone(),
                kind: s.kind,
                in_slot: s.in_slot,
                out_slot: s.out_slot,
                scratch_slot: match &s.op {
                    Op::Conv2dIm2col { scratch_slot, .. } => Some(*scratch_slot),
                    _ => None,
                },
                in_place: s.op.in_place(),
                strategy: s.op.strategy(),
                out_shape: s.out_shape.dims().to_vec(),
                macs: s.macs,
                est_us: s.est_us,
                precision: s.weight_dtype(),
                full_integer: s.op.full_integer(),
                par: s.par,
            })
            .collect()
    }

    /// Resolved intra-op lane ceiling this plan was compiled for.
    pub fn intra_threads(&self) -> usize {
        self.intra_threads
    }

    /// Whether any step runs the full-integer path.
    pub fn has_full_integer_steps(&self) -> bool {
        self.steps.iter().any(|s| s.op.full_integer())
    }

    /// Bytes of integer scratch (quantized activations, transposed
    /// patches, i32 accumulators) the arena holds for full-integer
    /// steps. Zero when no step runs full-integer. Reported separately
    /// from [`ExecutionPlan::peak_arena_bytes`], which stays the f32
    /// slot arena.
    pub fn quant_arena_bytes(&self) -> usize {
        self.quant_scratch_spec
            .map_or(0, |s| s.x + s.patches + s.acc * std::mem::size_of::<i32>())
    }

    /// `(layer name, chosen strategy)` for every conv2d step.
    pub fn conv_strategies(&self) -> Vec<(Arc<str>, ConvStrategy)> {
        self.steps
            .iter()
            .filter_map(|s| s.op.strategy().map(|st| (s.name.clone(), st)))
            .collect()
    }

    /// `(layer name, resident weight dtype)` for every weighted step.
    pub fn weight_precisions(&self) -> Vec<(Arc<str>, DType)> {
        self.steps
            .iter()
            .filter_map(|s| s.weight_dtype().map(|d| (s.name.clone(), d)))
            .collect()
    }

    /// Bytes of parameters this plan keeps resident, at each step's
    /// resident dtype (weights) plus f32 biases. For a pure-f32 plan this
    /// is exactly `param_count * 4`, which keeps the pool/cache byte
    /// accounting backward compatible.
    pub fn resident_weight_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.param_bytes).sum()
    }

    /// How many times the arena has been (re)built — 1 after any number
    /// of executes, which is the "zero steady-state allocation" invariant
    /// the tests pin down.
    pub fn arena_builds(&self) -> u64 {
        self.arena_builds.load(Ordering::Relaxed)
    }

    /// Human-readable plan dump: per-layer strategy, slot routing and
    /// the arena layout (`dlk plan`).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "plan `{}` batch {}: {} steps, {} arena slots, peak arena {}, resident weights {}, intra {} thread{}, est {:.1} us",
            self.arch_name,
            self.batch,
            self.steps.len(),
            self.slot_numel.len(),
            crate::metrics::fmt_bytes(self.peak_arena_bytes() as u64),
            crate::metrics::fmt_bytes(self.resident_weight_bytes() as u64),
            self.intra_threads,
            if self.intra_threads == 1 { "" } else { "s" },
            self.est_us
        );
        for (i, n) in self.slot_numel.iter().enumerate() {
            let _ = writeln!(
                s,
                "  slot {i}: {} elems ({})",
                n,
                crate::metrics::fmt_bytes((n * std::mem::size_of::<f32>()) as u64)
            );
        }
        if let Some((grid, chan)) = self.fft_scratch_spec {
            let _ = writeln!(
                s,
                "  fft scratch: {} complex elems",
                grid * 2 + chan
            );
        }
        if self.quant_scratch_spec.is_some() {
            let _ = writeln!(
                s,
                "  quant arena: {} (i8 activations + patches, i32 accumulators)",
                crate::metrics::fmt_bytes(self.quant_arena_bytes() as u64)
            );
        }
        for (i, step) in self.steps.iter().enumerate() {
            let route = if step.op.in_place() {
                format!("s{} in-place", step.in_slot)
            } else {
                match &step.op {
                    Op::Conv2dIm2col { scratch_slot, .. } => {
                        format!("s{}->s{} (scratch s{})", step.in_slot, step.out_slot, scratch_slot)
                    }
                    _ => format!("s{}->s{}", step.in_slot, step.out_slot),
                }
            };
            // Tag: conv strategy and/or non-f32 resident precision, e.g.
            // `[im2col i8]`, `[direct]`, `[f16]` (dense). Full-integer
            // steps tag as `i8i8` — quantized on both operands — to
            // distinguish them from weights-only `i8`.
            let strategy = {
                let strat = step.op.strategy().map(ConvStrategy::name);
                let prec = if step.op.full_integer() {
                    Some("i8i8")
                } else {
                    step.weight_dtype().filter(|d| *d != DType::F32).map(DType::name)
                };
                match (strat, prec) {
                    (Some(st), Some(p)) => format!(" [{st} {p}]"),
                    (Some(st), None) => format!(" [{st}]"),
                    (None, Some(p)) => format!(" [{p}]"),
                    (None, None) => String::new(),
                }
            };
            let dims: Vec<String> =
                step.out_shape.dims().iter().map(|d| d.to_string()).collect();
            // Per-step parallelism, e.g. ` x4t` (omitted for serial steps
            // so single-threaded dumps stay byte-identical to before).
            let threads = if step.par.threads > 1 {
                format!(" x{}t", step.par.threads)
            } else {
                String::new()
            };
            let _ = writeln!(
                s,
                "  step {i:2} {:<12} {:<14}{strategy:<9} {route:<24} -> [{}]  est {:.1} us{threads}",
                step.name,
                step.kind,
                dims.join("x"),
                step.est_us
            );
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Planned executor: plan cache over (arch, weights)
// ---------------------------------------------------------------------------

/// An architecture + weights bound to a cache of compiled
/// [`ExecutionPlan`]s, one per batch size — the planned counterpart of
/// [`super::CpuExecutor`]. `CpuModel` precompiles one plan per AOT-ladder
/// batch size at load; ad-hoc batch sizes compile on first use and are
/// cached.
pub struct PlannedExecutor {
    arch: Architecture,
    weights: Arc<WeightStore>,
    opts: PlanOptions,
    /// Resolved intra-op lane ceiling ([`resolve_intra_threads`] over
    /// [`PlanOptions::intra_threads`]).
    intra_threads: usize,
    /// The worker pool parallel steps fan out over. Lazily self-created
    /// on first forward when `intra_threads > 1`; the serving stack
    /// instead attaches its per-shard pool via
    /// [`PlannedExecutor::attach_pool`] so co-resident models share one
    /// pool and never oversubscribe the shard's lanes.
    pool: OnceLock<Option<Arc<KernelPool>>>,
    cache: Mutex<PlanCache>,
}

/// Per-executor compile cache: plans by batch size, plus the FFT filter
/// spectra and quantized resident weights shared by every plan (both are
/// batch-independent).
#[derive(Default)]
struct PlanCache {
    plans: BTreeMap<usize, Arc<ExecutionPlan>>,
    fft: BTreeMap<String, Arc<FftConvPlan>>,
    quant: BTreeMap<String, Arc<ResidentWeights>>,
}

impl PlannedExecutor {
    /// Bind an architecture to (shared) weights; validates them.
    pub fn new(
        arch: Architecture,
        weights: Arc<WeightStore>,
        opts: PlanOptions,
    ) -> crate::Result<PlannedExecutor> {
        weights.validate(&arch)?;
        Ok(PlannedExecutor {
            arch,
            weights,
            intra_threads: resolve_intra_threads(opts.intra_threads),
            opts,
            pool: OnceLock::new(),
            cache: Mutex::new(PlanCache::default()),
        })
    }

    /// Build with deterministic random weights — delegates the seeding
    /// to [`super::CpuExecutor::with_random_weights`] and shares the
    /// resulting store, so an interpreter oracle built with the same
    /// seed holds bit-identical weights.
    pub fn with_random_weights(
        arch: Architecture,
        seed: u64,
        opts: PlanOptions,
    ) -> crate::Result<PlannedExecutor> {
        let exec = super::CpuExecutor::with_random_weights(arch.clone(), seed)?;
        PlannedExecutor::new(arch, exec.shared_weights(), opts)
    }

    pub fn arch(&self) -> &Architecture {
        &self.arch
    }

    pub fn weights(&self) -> &WeightStore {
        &self.weights
    }

    pub fn options(&self) -> &PlanOptions {
        &self.opts
    }

    /// Resolved intra-op lane ceiling for this executor's forwards.
    pub fn intra_threads(&self) -> usize {
        self.intra_threads
    }

    /// Share an externally-owned worker pool (the engine's per-shard
    /// pool). Must be called before the first forward; later calls (or
    /// calls racing a lazily self-created pool) are ignored — the first
    /// pool wins, keeping every forward on one consistent pool.
    pub fn attach_pool(&self, pool: Arc<KernelPool>) {
        let _ = self.pool.set(Some(pool));
    }

    /// The pool forwards fan out over, self-creating it on first use
    /// when `intra_threads > 1` and no pool was attached.
    pub fn kernel_pool(&self) -> Option<&Arc<KernelPool>> {
        self.pool
            .get_or_init(|| {
                (self.intra_threads > 1).then(|| Arc::new(KernelPool::new(self.intra_threads)))
            })
            .as_ref()
    }

    /// The cached plan for `batch`, compiling it on first request. FFT
    /// filter spectra are shared across all of this executor's plans.
    pub fn plan_for(&self, batch: usize) -> crate::Result<Arc<ExecutionPlan>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(p) = cache.plans.get(&batch) {
            return Ok(p.clone());
        }
        let cache = &mut *cache;
        let plan = Arc::new(ExecutionPlan::compile_with_caches(
            &self.arch,
            &self.weights,
            batch,
            &self.opts,
            &mut cache.fft,
            &mut cache.quant,
        )?);
        cache.plans.insert(batch, plan.clone());
        Ok(plan)
    }

    /// Compile (and cache) a plan per batch size up front — what
    /// `CpuModel::load` does for the AOT ladder.
    pub fn precompile(&self, batches: &[usize]) -> crate::Result<()> {
        for &b in batches {
            self.plan_for(b)?;
        }
        Ok(())
    }

    /// Already-compiled plan for `batch`, if any.
    pub fn cached_plan(&self, batch: usize) -> Option<Arc<ExecutionPlan>> {
        self.cache.lock().unwrap().plans.get(&batch).cloned()
    }

    /// Number of compiled plans in the cache.
    pub fn plan_count(&self) -> usize {
        self.cache.lock().unwrap().plans.len()
    }

    /// Forward a `[batch, ...]` input through its batch's plan.
    pub fn forward(&self, input: &Tensor) -> crate::Result<Tensor> {
        anyhow::ensure!(input.shape().rank() >= 1, "input must have a batch dimension");
        let plan = self.plan_for(input.shape().dim(0))?;
        plan.execute_with_pool(&self.weights, input, self.kernel_pool().map(Arc::as_ref))
    }

    /// Forward with per-layer timings (interned names).
    pub fn forward_timed(&self, input: &Tensor) -> crate::Result<(Tensor, Vec<LayerTiming>)> {
        anyhow::ensure!(input.shape().rank() >= 1, "input must have a batch dimension");
        let plan = self.plan_for(input.shape().dim(0))?;
        plan.execute_timed_with_pool(&self.weights, input, self.kernel_pool().map(Arc::as_ref))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{lenet, nin_cifar10};
    use crate::nn::CpuExecutor;

    fn tiny_arch() -> Architecture {
        let mut a = Architecture::new("tiny-plan", &[1, 6, 6]);
        a.push("conv1", LayerKind::Conv2d { out_ch: 2, k: 3, stride: 1, pad: 1 });
        a.push("relu1", LayerKind::Relu);
        a.push("pool1", LayerKind::MaxPool2d { k: 2, stride: 2, pad: 0 });
        a.push("flatten", LayerKind::Flatten);
        a.push("fc", LayerKind::Dense { out: 3 });
        a.push("softmax", LayerKind::Softmax);
        a
    }

    #[test]
    fn plan_matches_interpreter_bit_exact_per_strategy() {
        let x = Tensor::randn(Shape::nchw(2, 1, 6, 6), 3, 1.0);
        for strat in [ConvStrategy::Direct, ConvStrategy::Im2col, ConvStrategy::Fft] {
            let mut oracle = CpuExecutor::with_random_weights(tiny_arch(), 9).unwrap();
            oracle.set_strategy(strat);
            let expect = oracle.forward(&x).unwrap();
            let planned =
                PlannedExecutor::with_random_weights(tiny_arch(), 9, PlanOptions::fixed(strat))
                    .unwrap();
            let got = planned.forward(&x).unwrap();
            assert_eq!(got.data(), expect.data(), "strategy {}", strat.name());
            assert_eq!(got.shape(), expect.shape());
        }
    }

    #[test]
    fn steady_state_reuses_the_arena() {
        let planned =
            PlannedExecutor::with_random_weights(tiny_arch(), 5, PlanOptions::default()).unwrap();
        let x = Tensor::randn(Shape::nchw(4, 1, 6, 6), 8, 1.0);
        let y1 = planned.forward(&x).unwrap();
        let y2 = planned.forward(&x).unwrap();
        assert_eq!(y1, y2);
        let plan = planned.cached_plan(4).unwrap();
        // One arena build across repeated executes: zero steady-state
        // allocation (the paper's "reuse memory between layers").
        assert_eq!(plan.arena_builds(), 1);
        assert_eq!(planned.plan_count(), 1);
    }

    #[test]
    fn arena_slots_never_overlap_while_live() {
        for batch in [1usize, 3] {
            let planned =
                PlannedExecutor::with_random_weights(lenet(), 7, PlanOptions::default()).unwrap();
            let plan = planned.plan_for(batch).unwrap();
            let bufs = plan.buffers();
            for (i, a) in bufs.iter().enumerate() {
                for b in &bufs[i + 1..] {
                    if a.slot == b.slot {
                        assert!(
                            a.death < b.birth || b.death < a.birth,
                            "buffers {a:?} and {b:?} share slot {} while both live",
                            a.slot
                        );
                    }
                }
            }
            // Liveness-based reuse must beat one-buffer-per-intermediate.
            assert!(plan.slot_sizes().len() < bufs.len());
            assert!(plan.peak_arena_bytes() > 0);
        }
    }

    #[test]
    fn auto_strategy_is_per_layer_on_nin() {
        // NIN mixes 5x5, 3x3 and 1x1 convs: with the (host-calibrated)
        // cost model the per-layer choice exists and every conv got one.
        let planned =
            PlannedExecutor::with_random_weights(nin_cifar10(), 4, PlanOptions::default())
                .unwrap();
        let plan = planned.plan_for(1).unwrap();
        let strategies = plan.conv_strategies();
        assert_eq!(strategies.len(), 9, "NIN has 9 conv layers");
        // And the dump names every one of them.
        let dump = plan.dump();
        assert!(dump.contains("conv1") && dump.contains("cccp6"), "{dump}");
        assert!(dump.contains("peak arena"), "{dump}");
    }

    #[test]
    fn fft_spectra_shared_across_ladder_plans() {
        // Filter spectra are batch-independent: every plan compiled by
        // one executor must hold the *same* Arc, not a recomputed copy.
        let planned = PlannedExecutor::with_random_weights(
            tiny_arch(),
            6,
            PlanOptions::fixed(ConvStrategy::Fft),
        )
        .unwrap();
        let p1 = planned.plan_for(1).unwrap();
        let p2 = planned.plan_for(2).unwrap();
        let spectra_of = |p: &ExecutionPlan| {
            p.steps
                .iter()
                .find_map(|s| match &s.op {
                    Op::Conv2dFft { fft } => Some(fft.clone()),
                    _ => None,
                })
                .expect("fixed-fft plan has an fft conv step")
        };
        assert!(Arc::ptr_eq(&spectra_of(&p1), &spectra_of(&p2)));
    }

    #[test]
    fn fixed_fft_precomputes_spectra_and_runs() {
        let planned = PlannedExecutor::with_random_weights(
            tiny_arch(),
            3,
            PlanOptions::fixed(ConvStrategy::Fft),
        )
        .unwrap();
        let plan = planned.plan_for(2).unwrap();
        assert!(plan.steps().iter().any(|s| s.strategy == Some(ConvStrategy::Fft)));
        let x = Tensor::randn(Shape::nchw(2, 1, 6, 6), 21, 1.0);
        let y = planned.forward(&x).unwrap();
        assert_eq!(y.shape().dims(), &[2, 3]);
    }

    #[test]
    fn plan_rejects_wrong_batch_and_shape() {
        let planned =
            PlannedExecutor::with_random_weights(tiny_arch(), 3, PlanOptions::default()).unwrap();
        let plan = planned.plan_for(2).unwrap();
        let wrong_batch = Tensor::zeros(Shape::nchw(3, 1, 6, 6));
        assert!(plan.execute(planned.weights(), &wrong_batch).is_err());
        let wrong_chan = Tensor::zeros(Shape::nchw(2, 2, 6, 6));
        assert!(plan.execute(planned.weights(), &wrong_chan).is_err());
        // The executor-level entry point routes to the right plan.
        assert!(planned.forward(&wrong_chan).is_err());
    }

    #[test]
    fn cost_model_orders_geometries_sanely() {
        let cm = CostModel::analytic();
        let p1 = Conv2dParams::new(1, 0);
        // 1x1 convs must never pick FFT (grid overhead dwarfs the MACs).
        let (s, _) = cm.pick_conv2d(1, 64, 8, 8, 64, 1, p1).unwrap();
        assert_ne!(s, ConvStrategy::Fft);
        // Costs are monotone in output channels for a fixed strategy.
        let small = cm.conv2d_us(ConvStrategy::Im2col, 1, 8, 16, 16, 8, 3, p1).unwrap();
        let large = cm.conv2d_us(ConvStrategy::Im2col, 1, 8, 16, 16, 32, 3, p1).unwrap();
        assert!(large > small);
        // Whole-forward estimates: NIN costs more than LeNet.
        let nin = cm.estimate_forward_us(&nin_cifar10(), 1).unwrap();
        let le = cm.estimate_forward_us(&lenet(), 1).unwrap();
        assert!(nin > le, "nin {nin} <= lenet {le}");
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in ["auto", "direct", "im2col", "fft"] {
            assert_eq!(PlanStrategy::parse(s).unwrap().name(), s);
        }
        assert!(PlanStrategy::parse("metal").is_err());
    }

    #[test]
    fn quantized_plans_execute_and_shrink_resident_bytes() {
        let base = PlanOptions::fixed(ConvStrategy::Im2col);
        let f32_exec = PlannedExecutor::with_random_weights(tiny_arch(), 9, base).unwrap();
        let x = Tensor::randn(Shape::nchw(2, 1, 6, 6), 13, 1.0);
        let y32 = f32_exec.forward(&x).unwrap();
        let f32_bytes = f32_exec.plan_for(2).unwrap().resident_weight_bytes();
        // Pure-f32 resident bytes are exactly param_count * 4.
        assert_eq!(f32_bytes, f32_exec.arch().param_count().unwrap() * 4);

        // Softmax outputs live in [0,1]: a small absolute band per
        // precision (the shared-harness tolerances in tests/plan.rs pin
        // the real contract). Full-integer int8 also quantizes the
        // activations, so its band is wider than the weights-only forms.
        for (precision, band) in [
            (PlanPrecision::F16, 0.05),
            (PlanPrecision::Int8Weights, 0.05),
            (PlanPrecision::Int8, 0.1),
        ] {
            let opts = PlanOptions { precision, ..base };
            let q = PlannedExecutor::with_random_weights(tiny_arch(), 9, opts).unwrap();
            let yq = q.forward(&x).unwrap();
            for (a, b) in yq.data().iter().zip(y32.data()) {
                assert!((a - b).abs() < band, "{}: {a} vs {b}", precision.name());
            }
            let q_bytes = q.plan_for(2).unwrap().resident_weight_bytes();
            assert!(
                q_bytes < f32_bytes,
                "{}: {q_bytes} >= {f32_bytes}",
                precision.name()
            );
            if precision != PlanPrecision::F16 {
                // Both i8 forms (packed panels pad the reduction depth to
                // a multiple of 4, so they carry a little slack) still
                // halve the resident footprint.
                assert!(q_bytes * 2 <= f32_bytes, "int8 resident {q_bytes} vs f32 {f32_bytes}");
            }
        }
    }

    #[test]
    fn full_integer_plans_allocate_quant_arena_and_execute() {
        // `int8` compiles the packed full-integer ops and sizes a shared
        // integer scratch arena; `int8-weights` keeps the old
        // dequantize-on-the-fly kernels (f32 patch scratch, no quant
        // arena).
        let base = PlanOptions::fixed(ConvStrategy::Im2col);
        let x = Tensor::randn(Shape::nchw(2, 1, 6, 6), 13, 1.0);
        let f32_exec = PlannedExecutor::with_random_weights(tiny_arch(), 9, base).unwrap();
        let y32 = f32_exec.forward(&x).unwrap();

        let wi = PlannedExecutor::with_random_weights(
            tiny_arch(),
            9,
            PlanOptions { precision: PlanPrecision::Int8Weights, ..base },
        )
        .unwrap();
        let p_wi = wi.plan_for(2).unwrap();
        assert!(!p_wi.has_full_integer_steps());
        assert_eq!(p_wi.quant_arena_bytes(), 0);
        assert!(p_wi.steps().iter().any(|s| s.scratch_slot.is_some()));

        let fi = PlannedExecutor::with_random_weights(
            tiny_arch(),
            9,
            PlanOptions { precision: PlanPrecision::Int8, ..base },
        )
        .unwrap();
        let p_fi = fi.plan_for(2).unwrap();
        assert!(p_fi.has_full_integer_steps());
        assert!(p_fi.quant_arena_bytes() > 0);
        // Full-integer im2col needs no f32 patch slot: its scratch is
        // the (4x smaller) integer arena.
        for s in p_fi.steps() {
            if s.full_integer {
                assert!(s.scratch_slot.is_none(), "{}", s.name);
                assert_eq!(s.precision, Some(DType::I8), "{}", s.name);
            }
        }
        let dump = p_fi.dump();
        assert!(dump.contains(" [im2col i8i8]"), "{dump}");
        assert!(dump.contains("quant arena"), "{dump}");

        // Both i8 forms track the f32 output; steady state reuses the
        // arena (integer scratch included — it is built with it).
        let y_wi = wi.forward(&x).unwrap();
        let y_fi = fi.forward(&x).unwrap();
        let _ = fi.forward(&x).unwrap();
        for (a, b) in y_wi.data().iter().zip(y32.data()) {
            assert!((a - b).abs() < 0.05, "int8-weights: {a} vs {b}");
        }
        for (a, b) in y_fi.data().iter().zip(y32.data()) {
            assert!((a - b).abs() < 0.1, "int8: {a} vs {b}");
        }
        assert_eq!(p_fi.arena_builds(), 1);
    }

    #[test]
    fn quantized_residency_shared_across_ladder_plans() {
        // Like FFT spectra, quantized weights are batch-independent: every
        // plan compiled by one executor must hold the same Arc.
        let opts = PlanOptions {
            precision: PlanPrecision::Int8,
            ..PlanOptions::fixed(ConvStrategy::Direct)
        };
        let planned = PlannedExecutor::with_random_weights(tiny_arch(), 6, opts).unwrap();
        let p1 = planned.plan_for(1).unwrap();
        let p2 = planned.plan_for(2).unwrap();
        let resident_of = |p: &ExecutionPlan, name: &str| {
            p.steps
                .iter()
                .find(|s| &*s.name == name)
                .and_then(|s| s.resident.clone())
                .expect("quantized step holds resident weights")
        };
        assert!(Arc::ptr_eq(&resident_of(&p1, "conv1"), &resident_of(&p2, "conv1")));
        assert!(Arc::ptr_eq(&resident_of(&p1, "fc"), &resident_of(&p2, "fc")));
    }

    #[test]
    fn forced_quantization_declines_fft_in_auto_mode() {
        // Auto strategy under a forced quantized precision must not pick
        // FFT (its resident form is f32 spectra, which would silently
        // undo the request)...
        let planned = PlannedExecutor::with_random_weights(
            tiny_arch(),
            3,
            PlanOptions::with_precision(PlanPrecision::Int8),
        )
        .unwrap();
        let plan = planned.plan_for(1).unwrap();
        for (name, st) in plan.conv_strategies() {
            assert_ne!(st, ConvStrategy::Fft, "{name}");
        }
        for (name, d) in plan.weight_precisions() {
            assert_eq!(d, DType::I8, "{name}");
        }

        // ...but an explicit Fixed(Fft) still wins: the conv stays
        // f32-resident while the dense layer quantizes.
        let opts = PlanOptions {
            precision: PlanPrecision::Int8,
            ..PlanOptions::fixed(ConvStrategy::Fft)
        };
        let planned = PlannedExecutor::with_random_weights(tiny_arch(), 3, opts).unwrap();
        let plan = planned.plan_for(1).unwrap();
        let precs: BTreeMap<String, DType> = plan
            .weight_precisions()
            .into_iter()
            .map(|(n, d)| (n.to_string(), d))
            .collect();
        assert_eq!(precs["conv1"], DType::F32);
        assert_eq!(precs["fc"], DType::I8);
        // Introspection agrees with the per-step view.
        let info = plan.steps();
        assert!(info.iter().any(|s| s.precision == Some(DType::I8)));
        assert!(info.iter().any(|s| s.precision == Some(DType::F32)));
    }

    #[test]
    fn auto_precision_mixes_layers_and_dump_tags_them() {
        // conv1d has no quantized kernel and stays f32; the dense layer
        // fits the default budget in some reduced form — a naturally
        // mixed-precision plan.
        let mut a = Architecture::new("mixed-1d", &[2, 16]);
        a.push("conv1", LayerKind::Conv1d { out_ch: 3, k: 3, stride: 1, pad: 1 });
        a.push("relu", LayerKind::Relu);
        a.push("flatten", LayerKind::Flatten);
        a.push("fc", LayerKind::Dense { out: 4 });
        a.push("softmax", LayerKind::Softmax);
        // Analytic coefficients keep the latency-aware pick
        // deterministic across hosts.
        let planned = PlannedExecutor::with_random_weights(
            a,
            17,
            PlanOptions {
                cost_model: Some(CostModel::analytic()),
                ..PlanOptions::with_precision(PlanPrecision::Auto)
            },
        )
        .unwrap();
        let plan = planned.plan_for(1).unwrap();
        let precs: BTreeMap<String, DType> = plan
            .weight_precisions()
            .into_iter()
            .map(|(n, d)| (n.to_string(), d))
            .collect();
        assert_eq!(precs["conv1"], DType::F32);
        assert_ne!(precs["fc"], DType::F32);
        // The dump names the resident total and tags the quantized step.
        let dump = plan.dump();
        assert!(dump.contains("resident weights"), "{dump}");
        assert!(
            dump.contains(" [f16]") || dump.contains(" [i8]") || dump.contains(" [i8i8]"),
            "quantized dense step untagged: {dump}"
        );
        // And it still runs.
        let x = Tensor::randn(Shape::new(&[1, 2, 16]), 23, 1.0);
        let y = planned.forward(&x).unwrap();
        assert_eq!(y.shape().dims(), &[1, 4]);
    }

    #[test]
    fn precision_parse_round_trips() {
        for s in ["f32", "f16", "int8", "int8-weights", "auto"] {
            assert_eq!(PlanPrecision::parse(s).unwrap().name(), s);
        }
        assert!(PlanPrecision::parse("bf16").is_err());
        assert_eq!(PlanPrecision::F32.estimate_bytes_per_param(), 4);
        assert_eq!(PlanPrecision::F16.estimate_bytes_per_param(), 2);
        assert_eq!(PlanPrecision::Int8.estimate_bytes_per_param(), 1);
        assert_eq!(PlanPrecision::Int8Weights.estimate_bytes_per_param(), 1);
        assert_eq!(PlanPrecision::Auto.estimate_bytes_per_param(), 4);
    }

    #[test]
    fn pick_precision_respects_budget() {
        let cm = CostModel::analytic();
        let w = Tensor::randn(Shape::new(&[16, 16]), 41, 1.0);
        // Zero or negative budget always means f32.
        assert_eq!(cm.pick_precision(&w, 0.0), DType::F32);
        assert_eq!(cm.pick_precision(&w, -1.0), DType::F32);
        // A generous budget admits i8 — smallest *and* fastest, since it
        // now prices as the packed full-integer GEMM.
        assert_eq!(cm.pick_precision(&w, 0.5), DType::I8);
        // A tensor with one huge outlier blows the i8 step size; a
        // moderate budget lands on f16 instead.
        let mut data = w.data().to_vec();
        data[0] = 1.0e4;
        let spiky = Tensor::new(Shape::new(&[16, 16]), data).unwrap();
        assert_eq!(cm.pick_precision(&spiky, 0.005), DType::F16);
    }

    #[test]
    fn parallelism_decision_is_overhead_aware() {
        let cm = CostModel::analytic();
        // Tiny steps stay serial no matter how many lanes are offered.
        assert_eq!(cm.parallelism(1.0, 64, 8), Parallelism::serial());
        // One lane (or one unit) is always serial.
        assert_eq!(cm.parallelism(1.0e6, 64, 1), Parallelism::serial());
        assert_eq!(cm.parallelism(1.0e6, 1, 8), Parallelism::serial());
        // Big steps split; the grain covers every unit.
        let p = cm.parallelism(1.0e5, 100, 4);
        assert_eq!(p, Parallelism { threads: 4, grain: 25 });
        assert_eq!(cm.parallelism(1.0e5, 10, 4).grain, 3); // ceil(10/4)
        // Units bound the fan-out.
        assert_eq!(cm.parallelism(1.0e5, 3, 8).threads, 3);
        // The adjusted estimate pays one fork-join on top of the split.
        assert!((cm.parallel_us(1.0e5, p) - (2.5e4 + cm.fork_join_us)).abs() < 1e-9);
        assert_eq!(cm.parallel_us(500.0, Parallelism::serial()), 500.0);
        // threads == 1 reduces the capped par pick to the serial pick.
        let params = Conv2dParams::new(1, 1);
        let a = cm.pick_conv2d_capped(2, 8, 16, 16, 32, 3, params).unwrap();
        let b = cm.pick_conv2d_capped_par(2, 8, 16, 16, 32, 3, params, 1).unwrap();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        // Parallel whole-forward estimates never exceed serial ones.
        let serial = cm.estimate_forward_us(&nin_cifar10(), 2).unwrap();
        let par4 = cm.estimate_forward_us_par(&nin_cifar10(), 2, 4).unwrap();
        assert!(par4 < serial, "par {par4} >= serial {serial}");
    }

    #[test]
    fn pooled_execution_is_bitwise_identical_to_serial() {
        // NiN at batch 2 with the analytic model compiles parallel steps
        // at 4 lanes; a pooled forward must match the serial one bit for
        // bit (fixed partitions, ordered writes).
        let opts = PlanOptions {
            cost_model: Some(CostModel::analytic()),
            ..PlanOptions::default()
        };
        let serial = PlannedExecutor::with_random_weights(
            nin_cifar10(),
            11,
            PlanOptions { intra_threads: 1, ..opts },
        )
        .unwrap();
        let pooled = PlannedExecutor::with_random_weights(
            nin_cifar10(),
            11,
            PlanOptions { intra_threads: 4, ..opts },
        )
        .unwrap();
        assert_eq!(pooled.intra_threads(), 4);
        let plan = pooled.plan_for(2).unwrap();
        assert_eq!(plan.intra_threads(), 4);
        assert!(
            plan.steps().iter().any(|s| s.par.threads > 1),
            "no step went parallel:\n{}",
            plan.dump()
        );
        // The dump surfaces per-step thread counts and the lane ceiling.
        let dump = plan.dump();
        assert!(dump.contains("intra 4 threads"), "{dump}");
        assert!(dump.contains(" x4t"), "{dump}");
        let x = Tensor::randn(Shape::nchw(2, 3, 32, 32), 17, 1.0);
        let ys = serial.forward(&x).unwrap();
        let yp = pooled.forward(&x).unwrap();
        assert_eq!(ys.data(), yp.data());
        // The pool actually ran work.
        let pool = pooled.kernel_pool().expect("intra 4 self-creates a pool");
        assert!(pool.dispatches() > 0);
        assert!(serial.kernel_pool().is_none(), "serial executor must not build a pool");
    }

    #[test]
    fn timed_execution_uses_interned_names() {
        let planned =
            PlannedExecutor::with_random_weights(tiny_arch(), 2, PlanOptions::default()).unwrap();
        let x = Tensor::randn(Shape::nchw(1, 1, 6, 6), 5, 1.0);
        let (_, t1) = planned.forward_timed(&x).unwrap();
        let (_, t2) = planned.forward_timed(&x).unwrap();
        assert_eq!(t1.len(), 6);
        assert_eq!(&*t1[0].name, "conv1");
        // Same Arc across calls: the name was interned once at compile.
        assert!(Arc::ptr_eq(&t1[0].name, &t2[0].name));
        assert!(t1[0].macs > 0);
        assert_eq!(t1[1].macs, 0); // relu
    }
}
