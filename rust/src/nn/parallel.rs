//! Intra-op fork-join parallelism: a small persistent worker pool that
//! lets one forward pass use every core the shard was budgeted.
//!
//! The paper's whole execution model is data-parallel — DeepLearningKit
//! runs each conv/GEMM as thousands of Metal threads — while our CPU
//! kernels were purely sequential loops on the shard's execute thread.
//! [`KernelPool`] is the CPU analogue of a Metal threadgroup: a fixed
//! set of persistent threads (std `mpsc`-free, `Mutex`/`Condvar` like
//! the engine's in-flight `Window`) that fork-join over **fixed,
//! size-deterministic partitions** of a kernel's output.
//!
//! Determinism contract (pinned by `rust/tests/parallel.rs`): a task
//! never splits a reduction (k) axis — every output element is computed
//! entirely inside one task, in the same inner-loop order as the serial
//! kernel — so results are **bitwise identical** to single-threaded
//! execution regardless of thread count or which worker claims which
//! chunk. Workers only ever write disjoint `&mut` output ranges
//! (arithmetically disjoint chunks of one buffer), preserving the PJRT
//! `!Send` invariant: the backend and its residents stay on the execute
//! thread; workers run pure closures over slices.
//!
//! Panic isolation: a panicking task is caught in the worker, its
//! payload is re-thrown from [`KernelPool::run`] on the dispatching
//! thread after the join barrier, and the pool survives — the engine's
//! existing `catch_unwind` turns it into a typed `ExecutionPanic` that
//! fails only that ticket.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Default intra-op thread count when nothing was configured: the
/// `DLK_INTRA_THREADS` environment variable (CI runs the tier-1 suite
/// under `=1` and `=4`), else 1 (serial — the pre-pool behavior).
pub fn default_intra_threads() -> usize {
    intra_threads_env().unwrap_or(1)
}

/// `DLK_INTRA_THREADS`, when set to a positive integer.
pub fn intra_threads_env() -> Option<usize> {
    std::env::var("DLK_INTRA_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// Resolve a configured intra-op thread count: `0` means "auto"
/// (environment override, else serial).
pub fn resolve_intra_threads(configured: usize) -> usize {
    if configured == 0 {
        default_intra_threads()
    } else {
        configured
    }
}

struct Job {
    /// Erased-lifetime pointer to the dispatcher's closure. Only valid
    /// while the dispatcher is blocked inside [`KernelPool::run`]; the
    /// join barrier there guarantees no worker holds it afterwards.
    f: *const (dyn Fn(usize) + Sync),
    /// Shared claim counter: workers and the dispatcher race on task
    /// indices. Which lane runs which task never affects results (tasks
    /// write disjoint ranges), only load balance.
    next: Arc<AtomicUsize>,
    tasks: usize,
}

// SAFETY: the raw closure pointer crosses threads, but it is only
// dereferenced between job publication and the join barrier in `run`,
// while the dispatcher (which owns the borrow) is blocked.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per dispatched job so a worker never re-enters a job
    /// it already finished.
    epoch: u64,
    job: Option<Job>,
    /// Workers currently inside the published job.
    active: usize,
    shutdown: bool,
    /// First panic payload caught in any lane of the current job.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new job or shutdown.
    work: Condvar,
    /// The dispatcher waits here for `active == 0`.
    done: Condvar,
    /// Cumulative nanoseconds lanes spent executing tasks (dispatcher
    /// lane included) — the numerator of the busy fraction surfaced in
    /// `ExecTrace`/`PoolUtilization`.
    busy_ns: AtomicU64,
    dispatches: AtomicU64,
}

/// A fixed-size fork-join worker pool. `threads` counts *lanes*
/// including the dispatching thread, so `KernelPool::new(4)` spawns 3
/// workers and `KernelPool::new(1)` spawns none (pure serial).
///
/// One job runs at a time; concurrent dispatchers serialize on an
/// internal lock (each engine shard owns one pool and dispatches from
/// its single execute thread, so this is uncontended in the serving
/// stack).
pub struct KernelPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes dispatchers; see type docs.
    dispatch: Mutex<()>,
}

impl KernelPool {
    pub fn new(threads: usize) -> KernelPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                shutdown: false,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            busy_ns: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("dlk-kern-{i}"))
                    .spawn(move || worker_main(shared))
                    .expect("spawn kernel worker")
            })
            .collect();
        KernelPool { shared, workers, threads, dispatch: Mutex::new(()) }
    }

    /// Total lanes (workers + the dispatching thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cumulative microseconds lanes spent executing tasks.
    pub fn busy_us(&self) -> u64 {
        self.shared.busy_ns.load(Ordering::Relaxed) / 1_000
    }

    /// Number of fork-join dispatches so far.
    pub fn dispatches(&self) -> u64 {
        self.shared.dispatches.load(Ordering::Relaxed)
    }

    /// Run `f(0), f(1), …, f(tasks-1)` across the pool's lanes and wait
    /// for all of them. The dispatcher participates, so a 1-lane pool
    /// (or `tasks <= 1`) degenerates to a plain in-order loop.
    ///
    /// If any task panics, the first payload is re-thrown from this call
    /// after every lane has finished; the pool remains usable.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.threads == 1 || tasks == 1 {
            let t0 = Instant::now();
            let r = (0..tasks).try_for_each(|i| catch_unwind(AssertUnwindSafe(|| f(i))));
            self.shared.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            if let Err(p) = r {
                resume_unwind(p);
            }
            return;
        }

        let _serialized = self.dispatch.lock().unwrap();
        let next = Arc::new(AtomicUsize::new(0));
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none() && st.active == 0);
            st.epoch += 1;
            // SAFETY: erased lifetime; the pointer outlives every use
            // because this function only returns after the join barrier
            // below observes `active == 0` with the job retracted.
            st.job = Some(Job {
                f: f as *const (dyn Fn(usize) + Sync),
                next: next.clone(),
                tasks,
            });
            self.shared.work.notify_all();
        }

        // Dispatcher lane: claim and run tasks alongside the workers.
        let t0 = Instant::now();
        let mut local_panic: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                // Keep claiming: the counter must exhaust so every task
                // is accounted for before the barrier releases.
                local_panic.get_or_insert(p);
            }
        }
        self.shared.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

        // Join barrier: retract the job (no late worker may pick it up),
        // then wait out the lanes that already joined it.
        let mut st = self.shared.state.lock().unwrap();
        st.job = None;
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        let pool_panic = st.panic.take();
        drop(st);
        if let Some(p) = local_panic.or(pool_panic) {
            resume_unwind(p);
        }
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(shared: Arc<Shared>) {
    let mut seen_epoch = 0u64;
    loop {
        // Wait for an unseen job (or shutdown), registering in `active`
        // under the lock so the dispatcher's barrier counts us.
        let (f, next, tasks) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = &st.job {
                    if st.epoch != seen_epoch {
                        seen_epoch = st.epoch;
                        st.active += 1;
                        break (job.f, job.next.clone(), job.tasks);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };

        let t0 = Instant::now();
        // SAFETY: the dispatcher cannot return from `run` (and thus the
        // closure cannot be dropped) until this lane decrements `active`.
        let f = unsafe { &*f };
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut st = shared.state.lock().unwrap();
                st.panic.get_or_insert(p);
            }
        }
        shared.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A borrowed parallelism context handed down to kernels: which pool to
/// fork on (if any) and how many lanes this step was budgeted by the
/// plan's [`Parallelism`](super::Parallelism) decision.
#[derive(Clone, Copy)]
pub struct Par<'a> {
    pool: Option<&'a KernelPool>,
    threads: usize,
}

impl<'a> Par<'a> {
    /// No parallelism: every `run_chunks` call is a plain in-order loop.
    pub fn serial() -> Par<'static> {
        Par { pool: None, threads: 1 }
    }

    /// Fork on `pool` with at most `threads` lanes (clamped to the
    /// pool's size; 0 or 1 means serial).
    pub fn new(pool: &'a KernelPool, threads: usize) -> Par<'a> {
        let threads = threads.clamp(1, pool.threads());
        Par { pool: (threads > 1).then_some(pool), threads }
    }

    pub fn threads(&self) -> usize {
        if self.pool.is_some() {
            self.threads
        } else {
            1
        }
    }

    /// Partition `units` work items into at most `threads` contiguous
    /// chunks — a **fixed, size-deterministic** split (`ceil(units /
    /// threads)` per chunk, independent of scheduling) — and run
    /// `f(lo, hi)` for each `[lo, hi)` range. Serial contexts run the
    /// chunks in order on the calling thread; the partition itself is
    /// identical either way.
    pub fn run_chunks(&self, units: usize, f: impl Fn(usize, usize) + Sync) {
        if units == 0 {
            return;
        }
        let lanes = self.threads().min(units);
        if lanes <= 1 {
            f(0, units);
            return;
        }
        let grain = units.div_ceil(lanes);
        let chunks = units.div_ceil(grain);
        match self.pool {
            Some(pool) => pool.run(chunks, &|c: usize| {
                let lo = c * grain;
                f(lo, (lo + grain).min(units));
            }),
            None => {
                for c in 0..chunks {
                    let lo = c * grain;
                    f(lo, (lo + grain).min(units));
                }
            }
        }
    }
}

/// A raw view over one contiguous output buffer that lets concurrent
/// tasks carve out *disjoint* `&mut` subranges (the `split_at_mut`
/// pattern, expressed index-wise so a chunked dispatch can claim its
/// range without threading a recursive split through the pool).
pub(crate) struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: tasks only touch disjoint ranges (see `slice`), so handing the
// view to multiple threads is as sound as `split_at_mut` would be.
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    pub(crate) fn new(slice: &'a mut [T]) -> UnsafeSlice<'a, T> {
        UnsafeSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// The `[lo, hi)` subslice.
    ///
    /// # Safety
    /// Callers must guarantee that ranges handed out to concurrently
    /// running tasks never overlap, and that the range is in bounds.
    /// Every kernel in this crate derives `[lo, hi)` from its chunk's
    /// partition indices, which are disjoint by construction.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_task_exactly_once() {
        let pool = KernelPool::new(4);
        for tasks in [1usize, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicU32> = (0..tasks).map(|_| AtomicU32::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
        assert!(pool.dispatches() >= 6);
    }

    #[test]
    fn single_lane_pool_is_serial_in_order() {
        let pool = KernelPool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(5, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn chunk_partition_is_size_deterministic() {
        // The partition depends only on (units, threads) — never on
        // scheduling — so chunk boundaries are reproducible.
        let pool = KernelPool::new(3);
        let par = Par::new(&pool, 3);
        let seen = Mutex::new(Vec::new());
        par.run_chunks(10, |lo, hi| seen.lock().unwrap().push((lo, hi)));
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 4), (4, 8), (8, 10)]);

        // Serial context: identical partition, in order.
        let mut serial = Vec::new();
        Par::serial().run_chunks(10, |lo, hi| serial.push((lo, hi)));
        assert_eq!(serial, vec![(0, 10)]);
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = KernelPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("task 5 poisoned");
                }
            });
        }));
        let payload = r.expect_err("panic must propagate to the dispatcher");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("poisoned"), "unexpected payload: {msg}");

        // The pool still serves the next job.
        let count = AtomicUsize::new(0);
        pool.run(16, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn busy_time_accumulates() {
        let pool = KernelPool::new(2);
        pool.run(4, &|_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(pool.busy_us() >= 4 * 2_000, "busy {}us", pool.busy_us());
    }

    #[test]
    fn intra_threads_resolution() {
        // Explicit values win; 0 falls back to env/default (this test
        // avoids mutating the environment — just the pure paths).
        assert_eq!(resolve_intra_threads(3), 3);
        assert!(resolve_intra_threads(0) >= 1);
    }
}
