//! Activation functions. The paper's Figure 3 shows exactly the rectifier
//! shader this module's [`relu`] mirrors; sigmoid/tanh round out the set for
//! imported models.

use crate::tensor::Tensor;

/// Rectifier: `max(0, x)` elementwise (paper Fig. 3/4).
pub fn relu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    relu_in_place(&mut out);
    out
}

/// In-place rectifier — the paper's roadmap item 5 ("more in-place
/// calculations to save memory"); the CPU executor uses this on
/// activation layers so no extra buffer is allocated.
pub fn relu_in_place(x: &mut Tensor) {
    for v in x.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Logistic sigmoid.
pub fn sigmoid(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data_mut() {
        *v = 1.0 / (1.0 + (-*v).exp());
    }
    out
}

/// Hyperbolic tangent (named `tanh_act` to avoid clashing with `f32::tanh`).
pub fn tanh_act(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data_mut() {
        *v = v.tanh();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    #[test]
    fn relu_clamps_negatives() {
        let x = Tensor::new(&[5][..], vec![-2.0, -0.5, 0.0, 0.5, 2.0]).unwrap();
        assert_eq!(relu(&x).data(), &[0.0, 0.0, 0.0, 0.5, 2.0]);
    }

    #[test]
    fn relu_in_place_matches() {
        let x = Tensor::randn(Shape::nchw(1, 2, 3, 3), 3, 1.0);
        let mut y = x.clone();
        relu_in_place(&mut y);
        assert_eq!(y.data(), relu(&x).data());
    }

    #[test]
    fn relu_idempotent() {
        let x = Tensor::randn(&[64][..], 4, 1.0);
        let once = relu(&x);
        let twice = relu(&once);
        assert_eq!(once.data(), twice.data());
    }

    #[test]
    fn sigmoid_range_and_symmetry() {
        let x = Tensor::new(&[3][..], vec![-10.0, 0.0, 10.0]).unwrap();
        let y = sigmoid(&x);
        assert!(y.data()[0] < 1e-4);
        assert!((y.data()[1] - 0.5).abs() < 1e-6);
        assert!(y.data()[2] > 0.9999);
        // sigmoid(-x) = 1 - sigmoid(x)
        assert!((y.data()[0] + y.data()[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn tanh_known_values() {
        let x = Tensor::new(&[2][..], vec![0.0, 1.0]).unwrap();
        let y = tanh_act(&x);
        assert_eq!(y.data()[0], 0.0);
        assert!((y.data()[1] - 0.7615942).abs() < 1e-6);
    }
}
