//! CPU graph executor: runs an [`Architecture`](crate::model::Architecture)
//! with a [`WeightStore`](crate::model::WeightStore) over NCHW batches.
//!
//! This is the "CPU baseline" half of every GPU-vs-CPU comparison in the
//! benches, and the independent oracle the integration tests hold the PJRT
//! path against. Per-layer timings feed experiment E9 (the NIN layer
//! breakdown).

use super::{
    avg_pool2d, conv1d, conv2d_direct, conv2d_fft, conv2d_im2col, dense, global_avg_pool,
    max_pool1d, max_pool2d, relu_in_place, softmax, Conv1dParams, Conv2dParams, ConvStrategy,
    Pool2dParams,
};
use crate::model::{Architecture, LayerKind, WeightStore};
use crate::tensor::{Shape, Tensor};
use std::sync::Arc;
use std::time::Instant;

/// Wall-time spent in one layer during [`CpuExecutor::forward_timed`]
/// (or a planned execution — see [`super::plan::ExecutionPlan`]).
#[derive(Clone, Debug)]
pub struct LayerTiming {
    /// Layer name, interned once at executor/plan build time: cloning
    /// it is a refcount bump, so timed forwards allocate no strings.
    pub name: Arc<str>,
    pub kind: &'static str,
    pub micros: f64,
    pub macs: u64,
}

/// CPU executor bound to one architecture + weights.
pub struct CpuExecutor {
    arch: Architecture,
    weights: Arc<WeightStore>,
    strategy: ConvStrategy,
    /// Interned layer names (shared with every `LayerTiming` emitted).
    names: Vec<Arc<str>>,
    /// Precomputed `<layer>.w` / `<layer>.b` keys so the hot loop never
    /// formats strings.
    weight_keys: Vec<(String, String)>,
}

impl CpuExecutor {
    /// Build an executor; validates weights against the architecture.
    pub fn new(arch: Architecture, weights: WeightStore) -> crate::Result<CpuExecutor> {
        weights.validate(&arch)?;
        let names = arch.layers.iter().map(|l| Arc::from(l.name.as_str())).collect();
        let weight_keys = arch
            .layers
            .iter()
            .map(|l| (format!("{}.w", l.name), format!("{}.b", l.name)))
            .collect();
        Ok(CpuExecutor {
            arch,
            weights: Arc::new(weights),
            strategy: ConvStrategy::Im2col,
            names,
            weight_keys,
        })
    }

    /// Build with random weights (latency benchmarking — numerics don't
    /// affect timing).
    pub fn with_random_weights(arch: Architecture, seed: u64) -> crate::Result<CpuExecutor> {
        let mut ws = WeightStore::new();
        for (i, (name, shape)) in arch.parameters()?.iter().enumerate() {
            let fan_in: usize = shape.dims().iter().skip(1).product::<usize>().max(1);
            let scale = (2.0 / fan_in as f32).sqrt();
            ws.insert(name, Tensor::randn(shape.clone(), seed.wrapping_add(i as u64), scale));
        }
        CpuExecutor::new(arch, ws)
    }

    pub fn set_strategy(&mut self, strategy: ConvStrategy) {
        self.strategy = strategy;
    }

    pub fn arch(&self) -> &Architecture {
        &self.arch
    }

    pub fn weights(&self) -> &WeightStore {
        &self.weights
    }

    /// Shared handle to the weights, so a
    /// [`PlannedExecutor`](super::plan::PlannedExecutor) can reuse them
    /// without duplicating the resident tensors.
    pub fn shared_weights(&self) -> Arc<WeightStore> {
        self.weights.clone()
    }

    fn run_conv2d(
        &self,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        p: Conv2dParams,
    ) -> crate::Result<Tensor> {
        match self.strategy {
            ConvStrategy::Direct => conv2d_direct(x, w, Some(b), p),
            ConvStrategy::Im2col => conv2d_im2col(x, w, Some(b), p),
            ConvStrategy::Fft => conv2d_fft(x, w, Some(b), p),
        }
    }

    /// Forward pass over a batch. Input shape `[batch, ...input_dims]`.
    pub fn forward(&self, input: &Tensor) -> crate::Result<Tensor> {
        Ok(self.forward_inner(input, None)?.0)
    }

    /// Forward pass recording per-layer wall time.
    pub fn forward_timed(&self, input: &Tensor) -> crate::Result<(Tensor, Vec<LayerTiming>)> {
        let mut timings = Vec::new();
        let out = self.forward_inner(input, Some(&mut timings))?.0;
        Ok((out, timings))
    }

    fn forward_inner(
        &self,
        input: &Tensor,
        mut timings: Option<&mut Vec<LayerTiming>>,
    ) -> crate::Result<(Tensor,)> {
        // Validate input shape: [batch] + arch.input.
        let expect: Vec<usize> = self.arch.input.clone();
        let got = input.shape().dims();
        anyhow::ensure!(
            got.len() == expect.len() + 1 && got[1..] == expect[..],
            "input shape {} does not match model input [N,{}]",
            input.shape(),
            expect.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
        );
        let batch = got[0];
        let layer_shapes = self.arch.shapes()?;

        let mut x = input.clone();
        for (i, layer) in self.arch.layers.iter().enumerate() {
            let t0 = Instant::now();
            let in_shape = &layer_shapes[i];
            let (wk, bk) = &self.weight_keys[i];
            x = match &layer.kind {
                LayerKind::Conv2d { stride, pad, .. } => {
                    let w = self.weights.get(wk)?;
                    let b = self.weights.get(bk)?;
                    self.run_conv2d(&x, w, b, Conv2dParams::new(*stride, *pad))?
                }
                LayerKind::Conv1d { k: _, stride, pad, .. } => {
                    let w = self.weights.get(wk)?;
                    let b = self.weights.get(bk)?;
                    conv1d(&x, w, Some(b), Conv1dParams { stride: *stride, pad: *pad })?
                }
                LayerKind::Relu => {
                    relu_in_place(&mut x);
                    x
                }
                LayerKind::MaxPool2d { k, stride, pad } => {
                    max_pool2d(&x, Pool2dParams::new(*k, *stride, *pad))?
                }
                LayerKind::AvgPool2d { k, stride, pad } => {
                    avg_pool2d(&x, Pool2dParams::new(*k, *stride, *pad))?
                }
                LayerKind::MaxPool1d { k, stride } => max_pool1d(&x, *k, *stride)?,
                LayerKind::GlobalAvgPool => global_avg_pool(&x)?,
                LayerKind::Dense { .. } => {
                    let w = self.weights.get(wk)?;
                    let b = self.weights.get(bk)?;
                    dense(&x, w, Some(b))?
                }
                LayerKind::Flatten => {
                    let flat: usize = in_shape.iter().product();
                    x.reshape(Shape::new(&[batch, flat]))?
                }
                LayerKind::Dropout { .. } => x, // inference no-op
                LayerKind::Softmax => softmax(&x)?,
            };
            if let Some(ts) = timings.as_deref_mut() {
                // Per-layer MACs scaled by batch.
                let layer_macs = {
                    let out = &layer_shapes[i + 1];
                    match &layer.kind {
                        LayerKind::Conv2d { out_ch, k, .. } => {
                            (out_ch * out[1] * out[2] * in_shape[0] * k * k) as u64
                        }
                        LayerKind::Conv1d { out_ch, k, .. } => {
                            (out_ch * out[1] * in_shape[0] * k) as u64
                        }
                        LayerKind::Dense { out: of } => {
                            (of * in_shape.iter().product::<usize>()) as u64
                        }
                        _ => 0,
                    }
                } * batch as u64;
                ts.push(LayerTiming {
                    name: self.names[i].clone(),
                    kind: layer.kind.type_name(),
                    micros: t0.elapsed().as_secs_f64() * 1e6,
                    macs: layer_macs,
                });
            }
        }
        Ok((x,))
    }

    /// Classify a batch: forward + per-row argmax.
    pub fn classify(&self, input: &Tensor) -> crate::Result<Vec<usize>> {
        let out = self.forward(input)?;
        anyhow::ensure!(out.shape().rank() == 2, "classify needs [batch, classes] output");
        Ok(out.argmax_rows())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{lenet, nin_cifar10, Architecture, LayerKind};

    fn tiny_arch() -> Architecture {
        let mut a = Architecture::new("tiny", &[1, 6, 6]);
        a.push("conv1", LayerKind::Conv2d { out_ch: 2, k: 3, stride: 1, pad: 1 });
        a.push("relu1", LayerKind::Relu);
        a.push("pool1", LayerKind::MaxPool2d { k: 2, stride: 2, pad: 0 });
        a.push("flatten", LayerKind::Flatten);
        a.push("fc", LayerKind::Dense { out: 3 });
        a.push("softmax", LayerKind::Softmax);
        a
    }

    #[test]
    fn forward_shapes_and_probabilities() {
        let exec = CpuExecutor::with_random_weights(tiny_arch(), 1).unwrap();
        let x = Tensor::randn(Shape::nchw(4, 1, 6, 6), 2, 1.0);
        let y = exec.forward(&x).unwrap();
        assert_eq!(y.shape().dims(), &[4, 3]);
        for row in y.data().chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn input_shape_validated() {
        let exec = CpuExecutor::with_random_weights(tiny_arch(), 1).unwrap();
        let bad = Tensor::zeros(Shape::nchw(1, 3, 6, 6));
        assert!(exec.forward(&bad).is_err());
        let missing_batch = Tensor::zeros(&[1, 6, 6][..]);
        assert!(exec.forward(&missing_batch).is_err());
    }

    #[test]
    fn deterministic_forward() {
        let exec = CpuExecutor::with_random_weights(tiny_arch(), 7).unwrap();
        let x = Tensor::randn(Shape::nchw(2, 1, 6, 6), 3, 1.0);
        let y1 = exec.forward(&x).unwrap();
        let y2 = exec.forward(&x).unwrap();
        assert_eq!(y1, y2);
    }

    #[test]
    fn strategies_agree() {
        let x = Tensor::randn(Shape::nchw(1, 1, 6, 6), 4, 1.0);
        let mut outs = Vec::new();
        for strat in [ConvStrategy::Direct, ConvStrategy::Im2col, ConvStrategy::Fft] {
            let mut exec = CpuExecutor::with_random_weights(tiny_arch(), 9).unwrap();
            exec.set_strategy(strat);
            outs.push(exec.forward(&x).unwrap());
        }
        crate::testutil::assert_allclose(outs[1].data(), outs[0].data(), 1e-4, 1e-5);
        crate::testutil::assert_allclose(outs[2].data(), outs[0].data(), 1e-3, 1e-4);
    }

    #[test]
    fn timed_forward_reports_all_layers() {
        let exec = CpuExecutor::with_random_weights(tiny_arch(), 1).unwrap();
        let x = Tensor::randn(Shape::nchw(1, 1, 6, 6), 2, 1.0);
        let (_, timings) = exec.forward_timed(&x).unwrap();
        assert_eq!(timings.len(), 6);
        assert_eq!(timings[0].kind, "conv2d");
        assert_eq!(&*timings[0].name, "conv1");
        assert!(timings[0].macs > 0);
        assert_eq!(timings[1].macs, 0); // relu
        // Names are interned at build time: two timed forwards hand out
        // the same Arc, not a fresh String per layer per call.
        let (_, again) = exec.forward_timed(&x).unwrap();
        assert!(std::sync::Arc::ptr_eq(&timings[0].name, &again[0].name));
    }

    #[test]
    fn lenet_runs_end_to_end() {
        let exec = CpuExecutor::with_random_weights(lenet(), 42).unwrap();
        let x = Tensor::randn(Shape::nchw(2, 1, 28, 28), 5, 1.0);
        let preds = exec.classify(&x).unwrap();
        assert_eq!(preds.len(), 2);
        assert!(preds.iter().all(|&p| p < 10));
    }

    #[test]
    fn nin_runs_end_to_end() {
        // The paper's actual 20-layer network, batch 1 (this is the E1 model).
        let exec = CpuExecutor::with_random_weights(nin_cifar10(), 42).unwrap();
        let x = Tensor::randn(Shape::nchw(1, 3, 32, 32), 6, 1.0);
        let y = exec.forward(&x).unwrap();
        assert_eq!(y.shape().dims(), &[1, 10]);
        let s: f32 = y.data().iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }

    #[test]
    fn weight_validation_enforced() {
        let arch = tiny_arch();
        let ws = WeightStore::new(); // empty
        assert!(CpuExecutor::new(arch, ws).is_err());
    }
}
