//! CPU reference neural-network backend.
//!
//! This is the paper's operator set — "convolution, pooling, rectifier
//! layer and softmax" (§1) — implemented in pure Rust over NCHW tensors.
//! It plays two roles:
//!
//! 1. **Baseline comparator.** The paper's predecessor work compared
//!    Metal-GPU against Apple's Accelerate CPU path; here `nn/` is the
//!    CPU path and the PJRT runtime (`runtime/`) is the "GPU" path.
//! 2. **Independent oracle.** Integration tests check PJRT executions of
//!    the AOT-compiled JAX models against this backend, which shares no
//!    code with JAX/XLA.
//!
//! Convolution comes in three strategies — direct, im2col+GEMM, and FFT
//! (the paper's roadmap item 1) — benchmarked against each other in E6.

mod activation;
mod conv;
mod conv1d;
mod dense;
mod fft;
mod fft_conv;
mod gemm_i8;
mod graph;
pub mod parallel;
pub mod plan;
mod pool;
mod softmax;

pub use activation::{relu, relu_in_place, sigmoid, tanh_act};
pub use conv::{
    conv2d, conv2d_direct, conv2d_direct_f16_into, conv2d_direct_f16_par_into,
    conv2d_direct_i8_into, conv2d_direct_i8_par_into, conv2d_direct_i8i8_into,
    conv2d_direct_i8i8_par_into, conv2d_direct_into, conv2d_direct_par_into, conv2d_im2col,
    conv2d_im2col_f16_into, conv2d_im2col_f16_par_into, conv2d_im2col_i8_into,
    conv2d_im2col_i8_par_into, conv2d_im2col_i8i8_into, conv2d_im2col_i8i8_par_into,
    conv2d_im2col_into, conv2d_im2col_par_into, im2col, im2col_into, im2col_par_into,
    Conv2dParams,
};
pub use conv1d::{conv1d, conv1d_into, max_pool1d, max_pool1d_into, Conv1dParams};
pub use dense::{
    dense, dense_f16_into, dense_f16_par_into, dense_i8_into, dense_i8_par_into, dense_i8i8_into,
    dense_i8i8_par_into, dense_into, dense_par_into, matmul, matmul_blocked, matmul_blocked_par,
};
pub use gemm_i8::{
    dot_i8, gemm_i8_i32, gemm_i8_i32_par, im2col_i8_transposed, im2col_i8_transposed_par,
    PackedI8, MAX_GEMM_K,
};
pub use parallel::{default_intra_threads, resolve_intra_threads, KernelPool, Par};
pub use fft::{fft, fft2d, ifft, ifft2d, Complex};
pub use fft_conv::{conv2d_fft, fft_conv_flops, FftConvPlan, FftScratch};
pub use graph::{CpuExecutor, LayerTiming};
pub use plan::{
    CostModel, ExecutionPlan, Parallelism, PlanOptions, PlanPrecision, PlanStrategy,
    PlannedExecutor,
};
pub use pool::{
    avg_pool2d, avg_pool2d_into, global_avg_pool, global_avg_pool_into, max_pool2d,
    max_pool2d_into, Pool2dParams,
};
pub use softmax::{log_softmax, softmax, softmax_in_place};

/// Convolution strategy selector (E6 sweeps all of these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvStrategy {
    Direct,
    Im2col,
    Fft,
}

impl ConvStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ConvStrategy::Direct => "direct",
            ConvStrategy::Im2col => "im2col",
            ConvStrategy::Fft => "fft",
        }
    }
}
