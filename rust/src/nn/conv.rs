//! 2-D convolution (cross-correlation, Caffe convention) over NCHW tensors.
//!
//! Three strategies:
//! - [`conv2d_direct`]: straightforward 7-loop implementation; the
//!   correctness anchor (mirrors the paper's Metal shader inner loop).
//! - [`conv2d_im2col`]: lower to patch-matrix + GEMM — the same
//!   restructuring the Pallas kernel uses to land on the MXU
//!   (DESIGN.md §Hardware-Adaptation), and the fast CPU path.
//! - FFT convolution lives in [`conv2d_fft`](super::conv2d_fft).
//!
//! The direct and im2col families also come in quantized-resident
//! variants (`*_i8_into`, `*_f16_into`) for ROADMAP item 2: weights stay
//! in their reduced form, inner loops accumulate over codes, and the
//! per-tensor i8 scale is folded into the epilogue so the bias remains
//! full-precision.

use crate::compression::{quantize_i8_into, requant_scale, symmetric_i8_scale, ResidentF16, ResidentI8};
use crate::tensor::{f16_lut, Shape, Tensor};

use super::gemm_i8::{dot_i8, gemm_i8_i32_par, im2col_i8_transposed_par, PackedI8};
use super::parallel::{Par, UnsafeSlice};

/// Convolution hyper-parameters (square kernel, symmetric padding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    pub stride: usize,
    pub pad: usize,
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: 1, pad: 0 }
    }
}

impl Conv2dParams {
    pub fn new(stride: usize, pad: usize) -> Self {
        Conv2dParams { stride, pad }
    }

    /// Output spatial size for an input of `(h, w)` and kernel `k`.
    pub fn out_hw(&self, h: usize, w: usize, k: usize) -> crate::Result<(usize, usize)> {
        anyhow::ensure!(self.stride > 0, "stride must be positive");
        anyhow::ensure!(
            h + 2 * self.pad >= k && w + 2 * self.pad >= k,
            "kernel {k} larger than padded input {}x{}",
            h + 2 * self.pad,
            w + 2 * self.pad
        );
        Ok((
            (h + 2 * self.pad - k) / self.stride + 1,
            (w + 2 * self.pad - k) / self.stride + 1,
        ))
    }
}

fn check_args(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
) -> crate::Result<(usize, usize, usize, usize, usize, usize)> {
    anyhow::ensure!(input.shape().rank() == 4, "conv2d input must be NCHW, got {}", input.shape());
    anyhow::ensure!(
        weight.shape().rank() == 4,
        "conv2d weight must be [out_ch, in_ch, k, k], got {}",
        weight.shape()
    );
    let (n, c, h, w) = (
        input.shape().dim(0),
        input.shape().dim(1),
        input.shape().dim(2),
        input.shape().dim(3),
    );
    let (oc, wc, kh, kw) = (
        weight.shape().dim(0),
        weight.shape().dim(1),
        weight.shape().dim(2),
        weight.shape().dim(3),
    );
    anyhow::ensure!(kh == kw, "only square kernels supported, got {kh}x{kw}");
    anyhow::ensure!(wc == c, "weight in_ch {wc} != input channels {c}");
    if let Some(b) = bias {
        anyhow::ensure!(
            b.numel() == oc,
            "bias has {} elements, expected {oc}",
            b.numel()
        );
    }
    Ok((n, c, h, w, oc, kh))
}

fn check_out(out: &Tensor, n: usize, oc: usize, oh: usize, ow: usize) -> crate::Result<()> {
    anyhow::ensure!(
        out.shape().dims() == [n, oc, oh, ow],
        "conv2d out tensor is {}, expected [{n},{oc},{oh},{ow}]",
        out.shape()
    );
    Ok(())
}

/// Direct (naive) convolution. O(N·OC·OH·OW·IC·K²).
pub fn conv2d_direct(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    let (n, c, h, w, oc, k) = check_args(input, weight, bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    let mut out = Tensor::zeros(Shape::nchw(n, oc, oh, ow));
    conv2d_direct_into(input, weight, bias, params, &mut out)?;
    Ok(out)
}

/// [`conv2d_direct`] writing into a preallocated `out` tensor (shape
/// `[n, oc, oh, ow]`); every output element is overwritten, so `out` may
/// hold stale data. This is the variant the execution plan dispatches
/// through so steady-state forward passes allocate nothing.
pub fn conv2d_direct_into(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_direct_par_into(input, weight, bias, params, out, Par::serial())
}

/// [`conv2d_direct_into`] partitioned over output channels (the
/// flattened `(batch, out_channel)` axis — each unit owns one contiguous
/// `oh*ow` output plane). Every element keeps the serial 7-loop
/// accumulation order, so outputs are bitwise identical at any thread
/// count.
pub fn conv2d_direct_par_into(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args(input, weight, bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let x = input.data();
    let wt = weight.data();
    let plane = oh * ow;
    let ov = UnsafeSlice::new(out.data_mut());

    par.run_chunks(n * oc, |lo, hi| {
        // SAFETY: chunks own disjoint ranges of (batch, out_ch) planes.
        let o = unsafe { ov.slice(lo * plane, hi * plane) };
        for idx in lo..hi {
            let (b, och) = (idx / oc, idx % oc);
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let oplane = &mut o[(idx - lo) * plane..(idx - lo + 1) * plane];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bias_v;
                    for ic in 0..c {
                        for ky in 0..k {
                            // Input row for this kernel row; skip out-of-pad rows.
                            let iy = (oy * params.stride + ky) as isize - params.pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let x_row = (b * c + ic) * h * w + iy as usize * w;
                            let w_row = ((och * c + ic) * k + ky) * k;
                            for kx in 0..k {
                                let ix = (ox * params.stride + kx) as isize - params.pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                acc += x[x_row + ix as usize] * wt[w_row + kx];
                            }
                        }
                    }
                    oplane[oy * ow + ox] = acc;
                }
            }
        }
    });
    Ok(())
}

/// Lower an NCHW image to the im2col patch matrix of shape
/// `[c*k*k, oh*ow]` for one batch element.
///
/// Each column is the receptive field of one output pixel; convolution then
/// becomes `weight[oc, c*k*k] @ patches[c*k*k, oh*ow]`.
pub fn im2col(
    input: &Tensor,
    batch: usize,
    k: usize,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    let c = input.shape().dim(1);
    let h = input.shape().dim(2);
    let w = input.shape().dim(3);
    let (oh, ow) = params.out_hw(h, w, k)?;
    let mut out = Tensor::zeros(Shape::new(&[c * k * k, oh * ow]));
    im2col_into(input, batch, k, params, &mut out)?;
    Ok(out)
}

/// [`im2col`] into a preallocated `[c*k*k, oh*ow]` patch matrix. With
/// padding the matrix is zeroed first, so padding cells stay correct
/// when the buffer is reused across batch elements or layers; without
/// padding every cell is written, so the memset is skipped.
pub fn im2col_into(
    input: &Tensor,
    batch: usize,
    k: usize,
    params: Conv2dParams,
    out: &mut Tensor,
) -> crate::Result<()> {
    im2col_par_into(input, batch, k, params, out, Par::serial())
}

/// [`im2col_into`] partitioned over patch rows (the `c*k*k` axis): each
/// chunk zero-fills its own rows (under padding) and then writes them,
/// so the matrix contents are identical to the serial lowering at any
/// thread count.
pub fn im2col_par_into(
    input: &Tensor,
    batch: usize,
    k: usize,
    params: Conv2dParams,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let c = input.shape().dim(1);
    let h = input.shape().dim(2);
    let w = input.shape().dim(3);
    let (oh, ow) = params.out_hw(h, w, k)?;
    let rows = c * k * k;
    let cols = oh * ow;
    anyhow::ensure!(
        out.shape().dims() == [rows, cols],
        "im2col out matrix is {}, expected [{rows},{cols}]",
        out.shape()
    );
    let x = input.data();
    let ov = UnsafeSlice::new(out.data_mut());
    let base = batch * c * h * w;

    par.run_chunks(rows, |r_lo, r_hi| {
        // SAFETY: chunks own disjoint patch-row bands [r_lo, r_hi).
        let o = unsafe { ov.slice(r_lo * cols, r_hi * cols) };
        if params.pad > 0 {
            // Out-of-image cells are only skipped (left zero) under padding.
            o.fill(0.0);
        }
        for row in r_lo..r_hi {
            // row ↔ (ic, ky, kx) in the serial lowering's iteration order.
            let (ic, ky, kx) = (row / (k * k), (row / k) % k, row % k);
            let out_row = &mut o[(row - r_lo) * cols..(row - r_lo + 1) * cols];
            for oy in 0..oh {
                let iy = (oy * params.stride + ky) as isize - params.pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // stays zero (padding)
                }
                let x_row = base + ic * h * w + iy as usize * w;
                let o_off = oy * ow;
                for ox in 0..ow {
                    let ix = (ox * params.stride + kx) as isize - params.pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    out_row[o_off + ox] = x[x_row + ix as usize];
                }
            }
        }
    });
    Ok(())
}

/// im2col + GEMM convolution. Same numerics as [`conv2d_direct`] (up to f32
/// association order), much better locality.
pub fn conv2d_im2col(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    let (n, c, h, w, oc, k) = check_args(input, weight, bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    let mut patches = Tensor::zeros(Shape::new(&[c * k * k, oh * ow]));
    let mut out = Tensor::zeros(Shape::nchw(n, oc, oh, ow));
    conv2d_im2col_into(input, weight, bias, params, &mut patches, &mut out)?;
    Ok(out)
}

/// [`conv2d_im2col`] writing into a preallocated `out` tensor, lowering
/// through a caller-provided `patches` scratch matrix of shape
/// `[c*k*k, oh*ow]` (the execution plan hands both out of its arena).
pub fn conv2d_im2col_into(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_im2col_par_into(input, weight, bias, params, patches, out, Par::serial())
}

/// [`conv2d_im2col_into`] with the lowering partitioned over patch rows
/// and the GEMM over output channels. Each output channel's broadcast-row
/// accumulation keeps the serial `r`-ascending order, so outputs are
/// bitwise identical at any thread count.
pub fn conv2d_im2col_par_into(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args(input, weight, bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let cols = oh * ow;
    let rows = c * k * k;

    // Weight viewed as [oc, rows] without copying.
    let wmat = weight.data();
    for b in 0..n {
        im2col_par_into(input, b, k, params, patches, par)?;
        let p = patches.data();
        let ov = UnsafeSlice::new(&mut out.data_mut()[b * oc * cols..(b + 1) * oc * cols]);
        // GEMM: out[ocH, cols] = W[oc, rows] x P[rows, cols]  (ikj order)
        par.run_chunks(oc, |lo, hi| {
            // SAFETY: chunks own disjoint output-channel bands [lo, hi).
            let o = unsafe { ov.slice(lo * cols, hi * cols) };
            for och in lo..hi {
                let orow = &mut o[(och - lo) * cols..(och - lo + 1) * cols];
                match bias {
                    Some(bv) => orow.fill(bv.data()[och]),
                    None => orow.fill(0.0),
                }
                for r in 0..rows {
                    let wv = wmat[och * rows + r];
                    if wv == 0.0 {
                        continue; // pruned-weight fast path (compression E4/E7)
                    }
                    let prow = &p[r * cols..(r + 1) * cols];
                    for (ov, pv) in orow.iter_mut().zip(prow.iter()) {
                        *ov += wv * pv;
                    }
                }
            }
        });
    }
    Ok(())
}

/// Shape checks for the quantized-resident kernels (mirrors
/// [`check_args`] with the weight given as dims instead of a tensor).
fn check_args_q(
    input: &Tensor,
    wdims: &[usize],
    bias: Option<&Tensor>,
) -> crate::Result<(usize, usize, usize, usize, usize, usize)> {
    anyhow::ensure!(input.shape().rank() == 4, "conv2d input must be NCHW, got {}", input.shape());
    anyhow::ensure!(
        wdims.len() == 4,
        "conv2d weight must be [out_ch, in_ch, k, k], got {wdims:?}"
    );
    let (n, c, h, w) = (
        input.shape().dim(0),
        input.shape().dim(1),
        input.shape().dim(2),
        input.shape().dim(3),
    );
    let (oc, wc, kh, kw) = (wdims[0], wdims[1], wdims[2], wdims[3]);
    anyhow::ensure!(kh == kw, "only square kernels supported, got {kh}x{kw}");
    anyhow::ensure!(wc == c, "weight in_ch {wc} != input channels {c}");
    if let Some(b) = bias {
        anyhow::ensure!(b.numel() == oc, "bias has {} elements, expected {oc}", b.numel());
    }
    Ok((n, c, h, w, oc, kh))
}

/// [`conv2d_direct_into`] with symmetric-i8 resident weights: the 7-loop
/// accumulates `x · code`, then the epilogue applies `acc * scale + bias`.
pub fn conv2d_direct_i8_into(
    input: &Tensor,
    weight: &ResidentI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_direct_i8_par_into(input, weight, bias, params, out, Par::serial())
}

/// [`conv2d_direct_i8_into`] partitioned over the flattened
/// `(batch, out_channel)` axis (same bitwise-determinism contract as
/// [`conv2d_direct_par_into`]).
pub fn conv2d_direct_i8_par_into(
    input: &Tensor,
    weight: &ResidentI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let x = input.data();
    let codes = weight.codes();
    let scale = weight.scale();
    let plane = oh * ow;
    let ov = UnsafeSlice::new(out.data_mut());

    par.run_chunks(n * oc, |lo, hi| {
        // SAFETY: chunks own disjoint ranges of (batch, out_ch) planes.
        let o = unsafe { ov.slice(lo * plane, hi * plane) };
        for idx in lo..hi {
            let (b, och) = (idx / oc, idx % oc);
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let oplane = &mut o[(idx - lo) * plane..(idx - lo + 1) * plane];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ic in 0..c {
                        for ky in 0..k {
                            let iy = (oy * params.stride + ky) as isize - params.pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let x_row = (b * c + ic) * h * w + iy as usize * w;
                            let w_row = ((och * c + ic) * k + ky) * k;
                            for kx in 0..k {
                                let ix = (ox * params.stride + kx) as isize - params.pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                acc += x[x_row + ix as usize] * codes[w_row + kx] as f32;
                            }
                        }
                    }
                    oplane[oy * ow + ox] = acc * scale + bias_v;
                }
            }
        }
    });
    Ok(())
}

/// [`conv2d_direct_into`] with f16-resident weights (lookup-table decode).
pub fn conv2d_direct_f16_into(
    input: &Tensor,
    weight: &ResidentF16,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_direct_f16_par_into(input, weight, bias, params, out, Par::serial())
}

/// [`conv2d_direct_f16_into`] partitioned over the flattened
/// `(batch, out_channel)` axis (same bitwise-determinism contract as
/// [`conv2d_direct_par_into`]).
pub fn conv2d_direct_f16_par_into(
    input: &Tensor,
    weight: &ResidentF16,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let x = input.data();
    let bits = weight.bits();
    let lut = f16_lut();
    let plane = oh * ow;
    let ov = UnsafeSlice::new(out.data_mut());

    par.run_chunks(n * oc, |lo, hi| {
        // SAFETY: chunks own disjoint ranges of (batch, out_ch) planes.
        let o = unsafe { ov.slice(lo * plane, hi * plane) };
        for idx in lo..hi {
            let (b, och) = (idx / oc, idx % oc);
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let oplane = &mut o[(idx - lo) * plane..(idx - lo + 1) * plane];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bias_v;
                    for ic in 0..c {
                        for ky in 0..k {
                            let iy = (oy * params.stride + ky) as isize - params.pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let x_row = (b * c + ic) * h * w + iy as usize * w;
                            let w_row = ((och * c + ic) * k + ky) * k;
                            for kx in 0..k {
                                let ix = (ox * params.stride + kx) as isize - params.pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                acc += x[x_row + ix as usize] * lut[bits[w_row + kx] as usize];
                            }
                        }
                    }
                    oplane[oy * ow + ox] = acc;
                }
            }
        }
    });
    Ok(())
}

/// [`conv2d_im2col_into`] with symmetric-i8 resident weights. The GEMM
/// runs over codes (keeping the zero-code pruned fast path — exact zeros
/// quantize to code 0), and the scale + bias land in a fused epilogue.
pub fn conv2d_im2col_i8_into(
    input: &Tensor,
    weight: &ResidentI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_im2col_i8_par_into(input, weight, bias, params, patches, out, Par::serial())
}

/// [`conv2d_im2col_i8_into`] with the lowering partitioned over patch
/// rows and the GEMM + epilogue over output channels (same
/// bitwise-determinism contract as [`conv2d_im2col_par_into`]).
pub fn conv2d_im2col_i8_par_into(
    input: &Tensor,
    weight: &ResidentI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let cols = oh * ow;
    let rows = c * k * k;

    let codes = weight.codes();
    let scale = weight.scale();
    for b in 0..n {
        im2col_par_into(input, b, k, params, patches, par)?;
        let p = patches.data();
        let ov = UnsafeSlice::new(&mut out.data_mut()[b * oc * cols..(b + 1) * oc * cols]);
        par.run_chunks(oc, |lo, hi| {
            // SAFETY: chunks own disjoint output-channel bands [lo, hi).
            let o = unsafe { ov.slice(lo * cols, hi * cols) };
            for och in lo..hi {
                let orow = &mut o[(och - lo) * cols..(och - lo + 1) * cols];
                orow.fill(0.0);
                for r in 0..rows {
                    let cv = codes[och * rows + r];
                    if cv == 0 {
                        continue; // pruned-weight fast path survives quantization
                    }
                    let wv = cv as f32;
                    let prow = &p[r * cols..(r + 1) * cols];
                    for (ov, pv) in orow.iter_mut().zip(prow.iter()) {
                        *ov += wv * pv;
                    }
                }
                let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
                for ov in orow.iter_mut() {
                    *ov = *ov * scale + bias_v;
                }
            }
        });
    }
    Ok(())
}

/// [`conv2d_direct_into`] over the *full-integer* path: the input is
/// quantized once per forward (per-tensor symmetric scale) into the
/// caller's i8 scratch, the 7-loop accumulates exact i8×i8→i32 with the
/// clipped kernel row reduced as one contiguous [`dot_i8`], and the
/// epilogue applies the fused `requant_scale(x_scale, w_scale)` plus the
/// full-precision bias.
pub fn conv2d_direct_i8i8_into(
    input: &Tensor,
    weight: &PackedI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    xq: &mut [i8],
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_direct_i8i8_par_into(input, weight, bias, params, xq, out, Par::serial())
}

/// [`conv2d_direct_i8i8_into`] with the activation quantization kept
/// serial (one linear pass) and the integer 7-loop partitioned over the
/// flattened `(batch, out_channel)` axis. Integer accumulation is
/// associative, and each element is still one task's exact i32 sum, so
/// outputs are bitwise identical at any thread count.
pub fn conv2d_direct_i8i8_par_into(
    input: &Tensor,
    weight: &PackedI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    xq: &mut [i8],
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let numel = input.numel();
    anyhow::ensure!(xq.len() >= numel, "i8 activation scratch too small");
    let x = input.data();
    let xs = symmetric_i8_scale(x);
    let xq = &mut xq[..numel];
    quantize_i8_into(x, xs, xq);
    let rs = requant_scale(xs, weight.scale());
    let wd = weight.data();
    let kp = weight.k_pad();
    let plane = oh * ow;
    let ov = UnsafeSlice::new(out.data_mut());
    let xq = &*xq; // shared read-only from here on

    par.run_chunks(n * oc, |lo, hi| {
        // SAFETY: chunks own disjoint ranges of (batch, out_ch) planes.
        let o = unsafe { ov.slice(lo * plane, hi * plane) };
        for idx in lo..hi {
            let (b, och) = (idx / oc, idx % oc);
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let wrow = &wd[och * kp..(och + 1) * kp];
            let oplane = &mut o[(idx - lo) * plane..(idx - lo + 1) * plane];
            for oy in 0..oh {
                for ox in 0..ow {
                    // Clip the kernel window against the image once; the
                    // surviving kx run is a contiguous i8 dot.
                    let x0 = ox * params.stride;
                    let kx_lo = params.pad.saturating_sub(x0);
                    let kx_hi = k.min((w + params.pad).saturating_sub(x0));
                    let mut acc = 0i32;
                    if kx_lo < kx_hi {
                        let ix0 = x0 + kx_lo - params.pad;
                        let run = kx_hi - kx_lo;
                        for ic in 0..c {
                            for ky in 0..k {
                                let iy =
                                    (oy * params.stride + ky) as isize - params.pad as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                let x_row = (b * c + ic) * h * w + iy as usize * w + ix0;
                                let w_row = (ic * k + ky) * k + kx_lo;
                                acc += dot_i8(&wrow[w_row..w_row + run], &xq[x_row..x_row + run]);
                            }
                        }
                    }
                    oplane[oy * ow + ox] = acc as f32 * rs + bias_v;
                }
            }
        }
    });
    Ok(())
}

/// [`conv2d_im2col_into`] over the *full-integer* path: quantize the
/// whole batch input once (per-tensor symmetric scale), lower each image
/// with the transposed i8 im2col, run the packed [`gemm_i8_i32`], and
/// requantize the exact i32 accumulators back to f32 in a fused epilogue
/// (`acc * requant_scale + bias`). All three scratch buffers come from
/// the plan's integer arena — steady-state forwards allocate nothing.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col_i8i8_into(
    input: &Tensor,
    weight: &PackedI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    xq: &mut [i8],
    patches_q: &mut [i8],
    acc: &mut [i32],
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_im2col_i8i8_par_into(input, weight, bias, params, xq, patches_q, acc, out, Par::serial())
}

/// [`conv2d_im2col_i8i8_into`] with the transposed lowering partitioned
/// over patch rows, the integer GEMM over `m`-panels (output channels;
/// the packed B-panel shared read-only), and the requant epilogue over
/// output channels. Integer accumulation plus per-element requant keeps
/// outputs bitwise identical to serial at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col_i8i8_par_into(
    input: &Tensor,
    weight: &PackedI8,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    xq: &mut [i8],
    patches_q: &mut [i8],
    acc: &mut [i32],
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let cols = oh * ow;
    let kp = weight.k_pad();
    let numel = input.numel();
    anyhow::ensure!(xq.len() >= numel, "i8 activation scratch too small");
    anyhow::ensure!(patches_q.len() >= cols * kp, "i8 patch scratch too small");
    anyhow::ensure!(acc.len() >= oc * cols, "i32 accumulator scratch too small");
    let x = input.data();
    let xs = symmetric_i8_scale(x);
    let xq = &mut xq[..numel];
    quantize_i8_into(x, xs, xq);
    let rs = requant_scale(xs, weight.scale());
    let acc = &mut acc[..oc * cols];

    for b in 0..n {
        let img = &xq[b * c * h * w..(b + 1) * c * h * w];
        im2col_i8_transposed_par(img, c, h, w, k, params, kp, patches_q, par);
        gemm_i8_i32_par(oc, cols, kp, weight.data(), patches_q, acc, par);
        let acc = &*acc;
        let ov = UnsafeSlice::new(&mut out.data_mut()[b * oc * cols..(b + 1) * oc * cols]);
        par.run_chunks(oc, |lo, hi| {
            // SAFETY: chunks own disjoint output-channel bands [lo, hi).
            let o = unsafe { ov.slice(lo * cols, hi * cols) };
            for och in lo..hi {
                let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
                let arow = &acc[och * cols..(och + 1) * cols];
                let orow = &mut o[(och - lo) * cols..(och - lo + 1) * cols];
                for (ov, &av) in orow.iter_mut().zip(arow) {
                    *ov = av as f32 * rs + bias_v;
                }
            }
        });
    }
    Ok(())
}

/// [`conv2d_im2col_into`] with f16-resident weights (lookup-table decode;
/// zero bit patterns keep the pruned fast path).
pub fn conv2d_im2col_f16_into(
    input: &Tensor,
    weight: &ResidentF16,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
) -> crate::Result<()> {
    conv2d_im2col_f16_par_into(input, weight, bias, params, patches, out, Par::serial())
}

/// [`conv2d_im2col_f16_into`] with the lowering partitioned over patch
/// rows and the GEMM over output channels (same bitwise-determinism
/// contract as [`conv2d_im2col_par_into`]).
pub fn conv2d_im2col_f16_par_into(
    input: &Tensor,
    weight: &ResidentF16,
    bias: Option<&Tensor>,
    params: Conv2dParams,
    patches: &mut Tensor,
    out: &mut Tensor,
    par: Par,
) -> crate::Result<()> {
    let (n, c, h, w, oc, k) = check_args_q(input, weight.dims(), bias)?;
    let (oh, ow) = params.out_hw(h, w, k)?;
    check_out(out, n, oc, oh, ow)?;
    let cols = oh * ow;
    let rows = c * k * k;

    let bits = weight.bits();
    let lut = f16_lut();
    for b in 0..n {
        im2col_par_into(input, b, k, params, patches, par)?;
        let p = patches.data();
        let ov = UnsafeSlice::new(&mut out.data_mut()[b * oc * cols..(b + 1) * oc * cols]);
        par.run_chunks(oc, |lo, hi| {
            // SAFETY: chunks own disjoint output-channel bands [lo, hi).
            let o = unsafe { ov.slice(lo * cols, hi * cols) };
            for och in lo..hi {
                let orow = &mut o[(och - lo) * cols..(och - lo + 1) * cols];
                match bias {
                    Some(bv) => orow.fill(bv.data()[och]),
                    None => orow.fill(0.0),
                }
                for r in 0..rows {
                    let wv = lut[bits[och * rows + r] as usize];
                    if wv == 0.0 {
                        continue;
                    }
                    let prow = &p[r * cols..(r + 1) * cols];
                    for (ov, pv) in orow.iter_mut().zip(prow.iter()) {
                        *ov += wv * pv;
                    }
                }
            }
        });
    }
    Ok(())
}

/// Default convolution entry point (im2col).
pub fn conv2d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    conv2d_im2col(input, weight, bias, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_allclose, Gen, XorShiftRng};

    #[test]
    fn identity_kernel_1x1() {
        // 1x1 kernel with weight 1.0 is identity per channel.
        let x = Tensor::randn(Shape::nchw(1, 1, 4, 4), 1, 1.0);
        let w = Tensor::new(&[1, 1, 1, 1][..], vec![1.0]).unwrap();
        let y = conv2d_direct(&x, &w, None, Conv2dParams::default()).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn known_3x3_sum_kernel() {
        // All-ones 3x3 kernel over an all-ones 3x3 input = 9.
        let x = Tensor::filled(Shape::nchw(1, 1, 3, 3), 1.0);
        let w = Tensor::filled(&[1, 1, 3, 3][..], 1.0);
        let y = conv2d_direct(&x, &w, None, Conv2dParams::default()).unwrap();
        assert_eq!(y.shape().dims(), &[1, 1, 1, 1]);
        assert_eq!(y.data()[0], 9.0);
    }

    #[test]
    fn padding_behaves_like_zeros() {
        let x = Tensor::filled(Shape::nchw(1, 1, 2, 2), 1.0);
        let w = Tensor::filled(&[1, 1, 3, 3][..], 1.0);
        let y = conv2d_direct(&x, &w, None, Conv2dParams::new(1, 1)).unwrap();
        // Center of padded 2x2 of ones: each output counts the in-bounds ones.
        assert_eq!(y.shape().dims(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[4.0, 4.0, 4.0, 4.0]);
    }

    #[test]
    fn stride_subsamples() {
        let x = Tensor::new(
            Shape::nchw(1, 1, 4, 4),
            (0..16).map(|i| i as f32).collect(),
        )
        .unwrap();
        let w = Tensor::new(&[1, 1, 1, 1][..], vec![1.0]).unwrap();
        let y = conv2d_direct(&x, &w, None, Conv2dParams::new(2, 0)).unwrap();
        assert_eq!(y.shape().dims(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn bias_added_per_channel() {
        let x = Tensor::filled(Shape::nchw(1, 1, 2, 2), 0.0);
        let w = Tensor::filled(&[2, 1, 1, 1][..], 1.0);
        let b = Tensor::new(&[2][..], vec![0.5, -1.5]).unwrap();
        let y = conv2d_direct(&x, &w, Some(&b), Conv2dParams::default()).unwrap();
        assert_eq!(&y.data()[..4], &[0.5; 4]);
        assert_eq!(&y.data()[4..], &[-1.5; 4]);
    }

    #[test]
    fn multi_channel_accumulates() {
        // Two input channels, kernel sums both.
        let mut x = Tensor::zeros(Shape::nchw(1, 2, 1, 1));
        x.set(&[0, 0, 0, 0], 2.0);
        x.set(&[0, 1, 0, 0], 3.0);
        let w = Tensor::filled(&[1, 2, 1, 1][..], 1.0);
        let y = conv2d_direct(&x, &w, None, Conv2dParams::default()).unwrap();
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn im2col_matches_direct_property() {
        crate::testutil::check(40, 101, Gen::conv_shape, |s| {
            let mut rng = XorShiftRng::new(s.h as u64 * 31 + s.k as u64);
            let x = Tensor::new(
                Shape::nchw(s.batch, s.in_ch, s.h, s.w),
                Gen::tensor_data(&mut rng, s.batch * s.in_ch * s.h * s.w),
            )
            .unwrap();
            let w = Tensor::new(
                &[s.out_ch, s.in_ch, s.k, s.k][..],
                Gen::tensor_data(&mut rng, s.out_ch * s.in_ch * s.k * s.k),
            )
            .unwrap();
            let b = Tensor::new(&[s.out_ch][..], Gen::tensor_data(&mut rng, s.out_ch)).unwrap();
            let p = Conv2dParams::new(s.stride, s.pad);
            let yd = conv2d_direct(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            let yi = conv2d_im2col(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            if yd.shape() != yi.shape() {
                return Err(format!("shape mismatch {} vs {}", yd.shape(), yi.shape()));
            }
            for (i, (&a, &bv)) in yd.data().iter().zip(yi.data()).enumerate() {
                if (a - bv).abs() > 1e-4 + 1e-4 * bv.abs() {
                    return Err(format!("mismatch at {i}: {a} vs {bv}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_bad_shapes() {
        let x = Tensor::zeros(Shape::nchw(1, 2, 4, 4));
        let w = Tensor::zeros(&[1, 3, 3, 3][..]); // wrong in_ch
        assert!(conv2d_direct(&x, &w, None, Conv2dParams::default()).is_err());
        let w2 = Tensor::zeros(&[1, 2, 5, 5][..]); // kernel larger than input
        assert!(conv2d_direct(&x, &w2, None, Conv2dParams::default()).is_err());
        let w3 = Tensor::zeros(&[1, 2, 3, 3][..]);
        let bad_bias = Tensor::zeros(&[2][..]);
        assert!(conv2d_direct(&x, &w3, Some(&bad_bias), Conv2dParams::default()).is_err());
    }

    #[test]
    fn im2col_layout() {
        // 1 channel, 2x2 input, k=1: patch matrix is the flattened image.
        let x = Tensor::new(Shape::nchw(1, 1, 2, 2), vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = im2col(&x, 0, 1, Conv2dParams::default()).unwrap();
        assert_eq!(p.shape().dims(), &[1, 4]);
        assert_eq!(p.data(), &[1.0, 2.0, 3.0, 4.0]);
        // k=2 with no padding: single output pixel, column = the 4 values.
        let p2 = im2col(&x, 0, 2, Conv2dParams::default()).unwrap();
        assert_eq!(p2.shape().dims(), &[4, 1]);
        assert_eq!(p2.data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // The plan reuses arena slots, so `_into` must be correct over
        // stale data: poison the buffers and demand bit-exact parity.
        let mut rng = XorShiftRng::new(77);
        let x = Tensor::new(Shape::nchw(2, 3, 6, 6), Gen::tensor_data(&mut rng, 216)).unwrap();
        let w = Tensor::new(&[4, 3, 3, 3][..], Gen::tensor_data(&mut rng, 108)).unwrap();
        let b = Tensor::new(&[4][..], Gen::tensor_data(&mut rng, 4)).unwrap();
        let p = Conv2dParams::new(1, 1);

        let expect = conv2d_direct(&x, &w, Some(&b), p).unwrap();
        let mut out = Tensor::filled(Shape::nchw(2, 4, 6, 6), f32::NAN);
        conv2d_direct_into(&x, &w, Some(&b), p, &mut out).unwrap();
        assert_eq!(out.data(), expect.data());

        let expect2 = conv2d_im2col(&x, &w, None, p).unwrap();
        let mut patches = Tensor::filled(&[27, 36][..], f32::NAN);
        let mut out2 = Tensor::filled(Shape::nchw(2, 4, 6, 6), f32::NAN);
        conv2d_im2col_into(&x, &w, None, p, &mut patches, &mut out2).unwrap();
        assert_eq!(out2.data(), expect2.data());

        // pad-0 skips the patch-matrix memset; a dirty scratch must still
        // be fully overwritten by the lowering.
        let p0 = Conv2dParams::new(1, 0);
        let expect0 = conv2d_im2col(&x, &w, Some(&b), p0).unwrap();
        let mut patches0 = Tensor::filled(&[27, 16][..], f32::NAN);
        let mut out0 = Tensor::filled(Shape::nchw(2, 4, 4, 4), f32::NAN);
        conv2d_im2col_into(&x, &w, Some(&b), p0, &mut patches0, &mut out0).unwrap();
        assert_eq!(out0.data(), expect0.data());

        // Mis-shaped out tensors are rejected, not silently clobbered.
        let mut bad = Tensor::zeros(Shape::nchw(1, 4, 6, 6));
        assert!(conv2d_direct_into(&x, &w, Some(&b), p, &mut bad).is_err());
        assert!(conv2d_im2col_into(&x, &w, None, p, &mut patches, &mut bad).is_err());
    }

    #[test]
    fn quantized_convs_match_dequantized_f32_kernels() {
        // Both quantized families must agree with the f32 kernels run on
        // the dequantized weights — isolating quantization error from
        // kernel error. f16 direct is bit-exact (same accumulation
        // order); i8 differs only by the scale epilogue rounding.
        let mut rng = XorShiftRng::new(123);
        let x = Tensor::new(Shape::nchw(2, 3, 7, 7), Gen::tensor_data(&mut rng, 294)).unwrap();
        let w = Tensor::new(&[4, 3, 3, 3][..], Gen::tensor_data(&mut rng, 108)).unwrap();
        let b = Tensor::new(&[4][..], Gen::tensor_data(&mut rng, 4)).unwrap();
        for p in [Conv2dParams::new(1, 1), Conv2dParams::new(2, 0)] {
            let (oh, ow) = p.out_hw(7, 7, 3).unwrap();

            let q = crate::compression::ResidentI8::quantize(&w);
            let wq = q.dequantize().unwrap();
            let expect_i8 = conv2d_direct(&x, &wq, Some(&b), p).unwrap();
            let mut got = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_direct_i8_into(&x, &q, Some(&b), p, &mut got).unwrap();
            assert_allclose(got.data(), expect_i8.data(), 1e-5, 1e-5);
            let mut patches = Tensor::filled(&[27, oh * ow][..], f32::NAN);
            let mut got2 = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_im2col_i8_into(&x, &q, Some(&b), p, &mut patches, &mut got2).unwrap();
            let expect_i8_gemm = conv2d_im2col(&x, &wq, Some(&b), p).unwrap();
            assert_allclose(got2.data(), expect_i8_gemm.data(), 1e-4, 1e-4);

            let hq = crate::compression::ResidentF16::quantize(&w);
            let wh = hq.dequantize().unwrap();
            let expect_f16 = conv2d_direct(&x, &wh, Some(&b), p).unwrap();
            let mut goth = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_direct_f16_into(&x, &hq, Some(&b), p, &mut goth).unwrap();
            assert_eq!(goth.data(), expect_f16.data(), "f16 direct bit-exact vs dequantized");
            let expect_f16_gemm = conv2d_im2col(&x, &wh, Some(&b), p).unwrap();
            let mut goth2 = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_im2col_f16_into(&x, &hq, Some(&b), p, &mut patches, &mut goth2).unwrap();
            assert_eq!(goth2.data(), expect_f16_gemm.data(), "f16 im2col bit-exact");
        }
    }

    #[test]
    fn full_integer_convs_match_f32_on_dequantized_operands() {
        // The i8i8 kernels quantize activations internally; running the
        // f32 kernel on the *dequantized* activations and weights
        // isolates requant rounding (one f32 multiply on an exact i32
        // accumulator) from quantization error. Direct and im2col share
        // the exact integer accumulator and the same epilogue, so they
        // must also agree with each other bit for bit.
        let mut rng = XorShiftRng::new(321);
        let x = Tensor::new(Shape::nchw(2, 3, 7, 7), Gen::tensor_data(&mut rng, 294)).unwrap();
        let w = Tensor::new(&[4, 3, 3, 3][..], Gen::tensor_data(&mut rng, 108)).unwrap();
        let b = Tensor::new(&[4][..], Gen::tensor_data(&mut rng, 4)).unwrap();
        for p in [Conv2dParams::new(1, 1), Conv2dParams::new(2, 0), Conv2dParams::new(1, 2)] {
            let (oh, ow) = p.out_hw(7, 7, 3).unwrap();
            let q = crate::compression::ResidentI8::quantize(&w);
            let packed = PackedI8::pack(&q);

            // Reference: f32 conv on dequantized activations + weights.
            let xs = symmetric_i8_scale(x.data());
            let mut xcodes = vec![0i8; x.numel()];
            quantize_i8_into(x.data(), xs, &mut xcodes);
            let x_deq = Tensor::new(
                x.shape().dims(),
                xcodes.iter().map(|&cv| cv as f32 * xs).collect::<Vec<_>>(),
            )
            .unwrap();
            let expect = conv2d_direct(&x_deq, &q.dequantize().unwrap(), Some(&b), p).unwrap();

            let mut xq = vec![i8::MIN; x.numel()]; // poisoned scratch
            let mut got_direct = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_direct_i8i8_into(&x, &packed, Some(&b), p, &mut xq, &mut got_direct).unwrap();
            assert_allclose(got_direct.data(), expect.data(), 1e-3, 1e-3);

            let cols = oh * ow;
            let mut patches_q = vec![i8::MIN; cols * packed.k_pad()];
            let mut acc = vec![i32::MIN; 4 * cols];
            let mut got_gemm = Tensor::filled(Shape::nchw(2, 4, oh, ow), f32::NAN);
            conv2d_im2col_i8i8_into(
                &x, &packed, Some(&b), p, &mut xq, &mut patches_q, &mut acc, &mut got_gemm,
            )
            .unwrap();
            assert_eq!(
                got_gemm.data(),
                got_direct.data(),
                "integer direct and im2col share exact accumulators ({p:?})"
            );
        }
    }

    #[test]
    fn full_integer_convs_reject_small_scratch() {
        let x = Tensor::zeros(Shape::nchw(1, 2, 4, 4));
        let w = Tensor::randn(&[3, 2, 3, 3][..], 8, 1.0);
        let packed = PackedI8::pack(&crate::compression::ResidentI8::quantize(&w));
        let p = Conv2dParams::new(1, 1);
        let mut out = Tensor::zeros(Shape::nchw(1, 3, 4, 4));
        let mut tiny = vec![0i8; 3];
        assert!(conv2d_direct_i8i8_into(&x, &packed, None, p, &mut tiny, &mut out).is_err());
        let mut xq = vec![0i8; 32];
        let mut acc = vec![0i32; 3 * 16];
        assert!(conv2d_im2col_i8i8_into(&x, &packed, None, p, &mut xq, &mut tiny, &mut acc, &mut out)
            .is_err());
        let mut patches_q = vec![0i8; 16 * packed.k_pad()];
        let mut tiny_acc = vec![0i32; 3];
        assert!(conv2d_im2col_i8i8_into(
            &x, &packed, None, p, &mut xq, &mut patches_q, &mut tiny_acc, &mut out
        )
        .is_err());
    }

    #[test]
    fn quantized_convs_preserve_pruned_zero_fast_path() {
        // Pruned (exactly zero) weights must quantize to code 0 / bit
        // pattern 0 and be skipped without changing results.
        let mut rng = XorShiftRng::new(6);
        let x = Tensor::new(Shape::nchw(1, 2, 5, 5), Gen::tensor_data(&mut rng, 50)).unwrap();
        let mut wdata = Gen::tensor_data(&mut rng, 3 * 2 * 9);
        for (i, v) in wdata.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
        }
        let w = Tensor::new(&[3, 2, 3, 3][..], wdata).unwrap();
        let p = Conv2dParams::new(1, 1);
        let q = crate::compression::ResidentI8::quantize(&w);
        for (&c, &v) in q.codes().iter().zip(w.data()) {
            if v == 0.0 {
                assert_eq!(c, 0);
            }
        }
        let reference = conv2d_direct(&x, &q.dequantize().unwrap(), None, p).unwrap();
        let mut patches = Tensor::zeros(&[18, 25][..]);
        let mut got = Tensor::zeros(Shape::nchw(1, 3, 5, 5));
        conv2d_im2col_i8_into(&x, &q, None, p, &mut patches, &mut got).unwrap();
        assert_allclose(got.data(), reference.data(), 1e-4, 1e-5);
    }

    #[test]
    fn quantized_convs_reject_bad_shapes() {
        let x = Tensor::zeros(Shape::nchw(1, 2, 4, 4));
        let w_bad_ch = Tensor::zeros(&[1, 3, 3, 3][..]);
        let q = crate::compression::ResidentI8::quantize(&w_bad_ch);
        let h = crate::compression::ResidentF16::quantize(&w_bad_ch);
        let p = Conv2dParams::default();
        let mut out = Tensor::zeros(Shape::nchw(1, 1, 2, 2));
        let mut patches = Tensor::zeros(&[27, 4][..]);
        assert!(conv2d_direct_i8_into(&x, &q, None, p, &mut out).is_err());
        assert!(conv2d_direct_f16_into(&x, &h, None, p, &mut out).is_err());
        assert!(conv2d_im2col_i8_into(&x, &q, None, p, &mut patches, &mut out).is_err());
        assert!(conv2d_im2col_f16_into(&x, &h, None, p, &mut patches, &mut out).is_err());
    }

    #[test]
    fn pruned_weights_fast_path_consistent() {
        // Zeros in the weight matrix must not change results (fast path skips).
        let mut rng = XorShiftRng::new(5);
        let x = Tensor::new(Shape::nchw(1, 2, 5, 5), Gen::tensor_data(&mut rng, 50)).unwrap();
        let mut wdata = Gen::tensor_data(&mut rng, 3 * 2 * 9);
        for (i, v) in wdata.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
        }
        let w = Tensor::new(&[3, 2, 3, 3][..], wdata).unwrap();
        let p = Conv2dParams::new(1, 1);
        let yd = conv2d_direct(&x, &w, None, p).unwrap();
        let yi = conv2d_im2col(&x, &w, None, p).unwrap();
        assert_allclose(yi.data(), yd.data(), 1e-4, 1e-5);
    }
}
