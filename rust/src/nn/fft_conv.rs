//! FFT-based 2-D convolution (paper roadmap item 1, benchmarked in E6).
//!
//! Convolution theorem: correlation in the spatial domain is pointwise
//! multiplication with the conjugate spectrum. Per (batch, out-channel)
//! pair we accumulate `IFFT( FFT(x_c) * conj(FFT(w_oc,c)) )` over input
//! channels, on a power-of-two padded grid. Filters are transformed once
//! per call ("precalculated convolution filters" — with a resident model
//! they would be cached; the E6 harness reports both amortized and
//! unamortized figures).

use super::conv::Conv2dParams;
use super::fft::{fft2d, ifft2d, Complex};
use crate::tensor::{Shape, Tensor};

/// A conv layer lowered to the frequency domain once, ahead of time: the
/// filter spectra are precomputed from the weights at plan-build time (the
/// paper's "precalculated convolution filters"), so a steady-state forward
/// pass only transforms the *input* — into caller-owned
/// [`FftScratch`] buffers, allocating nothing.
pub struct FftConvPlan {
    params: Conv2dParams,
    c: usize,
    oc: usize,
    k: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    gr: usize,
    gc: usize,
    /// `oc*c` filter spectra, each a `gr*gc` plane.
    filter_spectra: Vec<Complex>,
}

/// Reusable complex work buffers for [`FftConvPlan::run_into`]. One
/// scratch can serve several plans: buffers only need to be at least as
/// large as each plan's [`FftConvPlan::scratch_needs`].
pub struct FftScratch {
    /// One `gr*gc` plane (input transform + accumulator workspace).
    pub xspec: Vec<Complex>,
    /// One `gr*gc` plane (per-output-channel accumulator).
    pub acc: Vec<Complex>,
    /// `c` planes of `gr*gc` (per-channel input spectra for one batch
    /// element).
    pub channels: Vec<Complex>,
}

impl FftScratch {
    /// Scratch sized for `(grid, channel_planes)` elements (see
    /// [`FftConvPlan::scratch_needs`]).
    pub fn with_sizes(grid: usize, channel_planes: usize) -> FftScratch {
        FftScratch {
            xspec: vec![Complex::zero(); grid],
            acc: vec![Complex::zero(); grid],
            channels: vec![Complex::zero(); channel_planes],
        }
    }
}

impl FftConvPlan {
    /// Precompute the filter spectra for `weight` applied to `h`×`w`
    /// inputs with `params`.
    pub fn new(weight: &Tensor, h: usize, w: usize, params: Conv2dParams) -> crate::Result<FftConvPlan> {
        anyhow::ensure!(weight.shape().rank() == 4, "fft conv weight must be [oc,c,k,k]");
        let (oc, c, k, kw) = (
            weight.shape().dim(0),
            weight.shape().dim(1),
            weight.shape().dim(2),
            weight.shape().dim(3),
        );
        anyhow::ensure!(k == kw, "square kernels only");
        let (oh, ow) = params.out_hw(h, w, k)?;

        // Padded grid: must hold the padded input; power of two for radix-2.
        let gr = (h + 2 * params.pad).next_power_of_two();
        let gc = (w + 2 * params.pad).next_power_of_two();

        // Pre-transform all filters: spectra[oc][c] on the gr x gc grid.
        let wd = weight.data();
        let mut filter_spectra = vec![Complex::zero(); oc * c * gr * gc];
        for och in 0..oc {
            for ic in 0..c {
                let spec = &mut filter_spectra[(och * c + ic) * gr * gc..(och * c + ic + 1) * gr * gc];
                for ky in 0..k {
                    for kx in 0..k {
                        spec[ky * gc + kx] = Complex::new(wd[((och * c + ic) * k + ky) * k + kx], 0.0);
                    }
                }
                fft2d(spec, gr, gc);
            }
        }
        Ok(FftConvPlan { params, c, oc, k, h, w, oh, ow, gr, gc, filter_spectra })
    }

    /// `(grid, channel_planes)` element counts this plan needs from an
    /// [`FftScratch`].
    pub fn scratch_needs(&self) -> (usize, usize) {
        (self.gr * self.gc, self.c * self.gr * self.gc)
    }

    /// A scratch sized exactly for this plan.
    pub fn scratch(&self) -> FftScratch {
        let (grid, channels) = self.scratch_needs();
        FftScratch::with_sizes(grid, channels)
    }

    /// Bytes held by the precomputed filter spectra (plan debug dumps).
    pub fn spectra_bytes(&self) -> usize {
        self.filter_spectra.len() * std::mem::size_of::<Complex>()
    }

    /// Kernel size the spectra were built for.
    pub fn kernel(&self) -> usize {
        self.k
    }

    /// The padded power-of-two FFT grid, `(rows, cols)`.
    pub fn grid(&self) -> (usize, usize) {
        (self.gr, self.gc)
    }

    /// Run the convolution for `input` (`[n, c, h, w]`, matching the plan)
    /// into the preallocated `out` (`[n, oc, oh, ow]`). Identical numerics
    /// to [`conv2d_fft`].
    pub fn run_into(
        &self,
        input: &Tensor,
        bias: Option<&Tensor>,
        scratch: &mut FftScratch,
        out: &mut Tensor,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            input.shape().dims().len() == 4
                && input.shape().dim(1) == self.c
                && input.shape().dim(2) == self.h
                && input.shape().dim(3) == self.w,
            "fft conv plan expects [n,{},{},{}] input, got {}",
            self.c,
            self.h,
            self.w,
            input.shape()
        );
        if let Some(b) = bias {
            anyhow::ensure!(b.numel() == self.oc, "bias has {} elements, expected {}", b.numel(), self.oc);
        }
        let n = input.shape().dim(0);
        anyhow::ensure!(
            out.shape().dims() == [n, self.oc, self.oh, self.ow],
            "fft conv out tensor is {}, expected [{n},{},{},{}]",
            out.shape(),
            self.oc,
            self.oh,
            self.ow
        );
        let (grid, chan) = self.scratch_needs();
        anyhow::ensure!(
            scratch.xspec.len() >= grid && scratch.acc.len() >= grid && scratch.channels.len() >= chan,
            "fft scratch too small: needs grid {grid} / channels {chan}"
        );
        let (c, oc, h, w, oh, ow, gr, gc) =
            (self.c, self.oc, self.h, self.w, self.oh, self.ow, self.gr, self.gc);
        let pad = self.params.pad;
        let stride = self.params.stride;

        let x = input.data();
        let o = out.data_mut();
        let xspec = &mut scratch.xspec[..grid];
        let acc = &mut scratch.acc[..grid];
        let channel_spectra = &mut scratch.channels[..chan];
        for b in 0..n {
            // Transform each input channel once per batch element.
            for ic in 0..c {
                xspec.iter_mut().for_each(|v| *v = Complex::zero());
                let plane = &x[(b * c + ic) * h * w..(b * c + ic + 1) * h * w];
                for iy in 0..h {
                    for ix in 0..w {
                        // Shift by pad so index 0 is the padded border.
                        xspec[(iy + pad) * gc + (ix + pad)] = Complex::new(plane[iy * w + ix], 0.0);
                    }
                }
                fft2d(xspec, gr, gc);
                channel_spectra[ic * grid..(ic + 1) * grid].copy_from_slice(xspec);
            }
            for och in 0..oc {
                acc.iter_mut().for_each(|v| *v = Complex::zero());
                for ic in 0..c {
                    let fs = &self.filter_spectra[(och * c + ic) * grid..(och * c + ic + 1) * grid];
                    let cs = &channel_spectra[ic * grid..(ic + 1) * grid];
                    // Correlation: X(f) * conj(W(f)).
                    for ((a, &xv), &wv) in acc.iter_mut().zip(cs.iter()).zip(fs.iter()) {
                        *a = a.add(xv.mul(wv.conj()));
                    }
                }
                ifft2d(acc, gr, gc);
                let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
                let orow = &mut o[((b * oc + och) * oh) * ow..((b * oc + och) * oh + oh) * ow];
                for oy in 0..oh {
                    for ox in 0..ow {
                        orow[oy * ow + ox] = acc[(oy * stride) * gc + ox * stride].re + bias_v;
                    }
                }
            }
        }
        Ok(())
    }
}

/// FFT convolution with the same semantics as [`super::conv2d_direct`].
/// One-shot wrapper over [`FftConvPlan`]: transforms the filters, runs,
/// and discards the plan (a resident model keeps the plan instead — see
/// `nn::plan`).
pub fn conv2d_fft(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    anyhow::ensure!(input.shape().rank() == 4 && weight.shape().rank() == 4, "NCHW expected");
    let (n, c, h, w) = (
        input.shape().dim(0),
        input.shape().dim(1),
        input.shape().dim(2),
        input.shape().dim(3),
    );
    anyhow::ensure!(
        weight.shape().dim(1) == c,
        "weight in_ch {} != input {c}",
        weight.shape().dim(1)
    );
    let plan = FftConvPlan::new(weight, h, w, params)?;
    let mut scratch = plan.scratch();
    let mut out = Tensor::zeros(Shape::nchw(n, plan.oc, plan.oh, plan.ow));
    plan.run_into(input, bias, &mut scratch, &mut out)?;
    Ok(out)
}

/// FLOP estimate for one FFT conv call (used by E6's model columns).
pub fn fft_conv_flops(
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    oc: usize,
    k: usize,
    pad: usize,
) -> u64 {
    let gr = (h + 2 * pad).next_power_of_two() as u64;
    let gc = (w + 2 * pad).next_power_of_two() as u64;
    let grid = gr * gc;
    let fft_cost = 5 * grid * (grid as f64).log2() as u64; // ~5N log N per 2-D FFT
    let n = n as u64;
    let c = c as u64;
    let oc = oc as u64;
    let _ = k;
    // filters: oc*c ffts; inputs: n*c ffts; outputs: n*oc iffts; pointwise: n*oc*c*grid*6
    (oc * c + n * c + n * oc) * fft_cost + n * oc * c * grid * 6
}

#[cfg(test)]
mod tests {
    use super::super::conv::conv2d_direct;
    use super::*;
    use crate::testutil::{Gen, XorShiftRng};

    #[test]
    fn matches_direct_small() {
        let mut rng = XorShiftRng::new(61);
        let x = Tensor::new(Shape::nchw(1, 1, 5, 5), Gen::tensor_data(&mut rng, 25)).unwrap();
        let w = Tensor::new(&[1, 1, 3, 3][..], Gen::tensor_data(&mut rng, 9)).unwrap();
        let p = Conv2dParams::new(1, 0);
        let yd = conv2d_direct(&x, &w, None, p).unwrap();
        let yf = conv2d_fft(&x, &w, None, p).unwrap();
        crate::testutil::assert_allclose(yf.data(), yd.data(), 1e-3, 1e-4);
    }

    #[test]
    fn matches_direct_property() {
        crate::testutil::check(25, 303, Gen::conv_shape, |s| {
            let mut rng = XorShiftRng::new((s.w * 131 + s.out_ch) as u64);
            let x = Tensor::new(
                Shape::nchw(s.batch, s.in_ch, s.h, s.w),
                Gen::tensor_data(&mut rng, s.batch * s.in_ch * s.h * s.w),
            )
            .unwrap();
            let w = Tensor::new(
                &[s.out_ch, s.in_ch, s.k, s.k][..],
                Gen::tensor_data(&mut rng, s.out_ch * s.in_ch * s.k * s.k),
            )
            .unwrap();
            let b = Tensor::new(&[s.out_ch][..], Gen::tensor_data(&mut rng, s.out_ch)).unwrap();
            let p = Conv2dParams::new(s.stride, s.pad);
            let yd = conv2d_direct(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            let yf = conv2d_fft(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            for (i, (&a, &e)) in yf.data().iter().zip(yd.data()).enumerate() {
                if (a - e).abs() > 2e-3 + 1e-3 * e.abs() {
                    return Err(format!("mismatch at {i}: fft={a} direct={e} ({s:?})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn plan_reuse_matches_one_shot_bit_exact() {
        let mut rng = XorShiftRng::new(63);
        let x = Tensor::new(Shape::nchw(2, 3, 7, 7), Gen::tensor_data(&mut rng, 294)).unwrap();
        let w = Tensor::new(&[2, 3, 3, 3][..], Gen::tensor_data(&mut rng, 54)).unwrap();
        let b = Tensor::new(&[2][..], Gen::tensor_data(&mut rng, 2)).unwrap();
        let p = Conv2dParams::new(1, 1);
        let expect = conv2d_fft(&x, &w, Some(&b), p).unwrap();

        let plan = FftConvPlan::new(&w, 7, 7, p).unwrap();
        assert_eq!(plan.kernel(), 3);
        assert_eq!(plan.grid(), (16, 16)); // 7+2 rounded up to a power of two
        assert!(plan.spectra_bytes() > 0);
        let mut scratch = plan.scratch();
        let mut out = Tensor::filled(Shape::nchw(2, 2, 7, 7), f32::NAN);
        plan.run_into(&x, Some(&b), &mut scratch, &mut out).unwrap();
        assert_eq!(out.data(), expect.data());
        // Re-run over the now-dirty scratch and output: identical again.
        plan.run_into(&x, Some(&b), &mut scratch, &mut out).unwrap();
        assert_eq!(out.data(), expect.data());
        // Undersized scratch is rejected.
        let mut small = FftScratch::with_sizes(4, 4);
        assert!(plan.run_into(&x, Some(&b), &mut small, &mut out).is_err());
    }

    #[test]
    fn strided_fft_conv() {
        let mut rng = XorShiftRng::new(62);
        let x = Tensor::new(Shape::nchw(1, 2, 8, 8), Gen::tensor_data(&mut rng, 128)).unwrap();
        let w = Tensor::new(&[2, 2, 3, 3][..], Gen::tensor_data(&mut rng, 36)).unwrap();
        let p = Conv2dParams::new(2, 1);
        let yd = conv2d_direct(&x, &w, None, p).unwrap();
        let yf = conv2d_fft(&x, &w, None, p).unwrap();
        assert_eq!(yd.shape(), yf.shape());
        crate::testutil::assert_allclose(yf.data(), yd.data(), 1e-3, 1e-3);
    }

    #[test]
    fn flop_model_monotone_in_kernel_grid() {
        // FFT cost is flat in k (grid-dominated) while direct grows with k².
        let small = fft_conv_flops(1, 16, 32, 32, 16, 3, 1);
        let large = fft_conv_flops(1, 16, 32, 32, 16, 11, 5);
        // Larger pad -> larger grid, but same order of magnitude.
        assert!(large >= small);
        assert!(large < small * 8);
    }
}
