//! FFT-based 2-D convolution (paper roadmap item 1, benchmarked in E6).
//!
//! Convolution theorem: correlation in the spatial domain is pointwise
//! multiplication with the conjugate spectrum. Per (batch, out-channel)
//! pair we accumulate `IFFT( FFT(x_c) * conj(FFT(w_oc,c)) )` over input
//! channels, on a power-of-two padded grid. Filters are transformed once
//! per call ("precalculated convolution filters" — with a resident model
//! they would be cached; the E6 harness reports both amortized and
//! unamortized figures).

use super::conv::Conv2dParams;
use super::fft::{fft2d, ifft2d, Complex};
use crate::tensor::{Shape, Tensor};

/// FFT convolution with the same semantics as [`super::conv2d_direct`].
pub fn conv2d_fft(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv2dParams,
) -> crate::Result<Tensor> {
    anyhow::ensure!(input.shape().rank() == 4 && weight.shape().rank() == 4, "NCHW expected");
    let (n, c, h, w) = (
        input.shape().dim(0),
        input.shape().dim(1),
        input.shape().dim(2),
        input.shape().dim(3),
    );
    let (oc, wc, k, kw) = (
        weight.shape().dim(0),
        weight.shape().dim(1),
        weight.shape().dim(2),
        weight.shape().dim(3),
    );
    anyhow::ensure!(k == kw, "square kernels only");
    anyhow::ensure!(wc == c, "weight in_ch {wc} != input {c}");
    let (oh, ow) = params.out_hw(h, w, k)?;

    // Padded grid: must hold the padded input; power of two for radix-2.
    let ph = h + 2 * params.pad;
    let pw = w + 2 * params.pad;
    let gr = ph.next_power_of_two();
    let gc = pw.next_power_of_two();

    // Pre-transform all filters: spectra[oc][c] on the gr x gc grid.
    let wd = weight.data();
    let mut filter_spectra = vec![vec![Complex::zero(); gr * gc]; oc * c];
    for och in 0..oc {
        for ic in 0..c {
            let spec = &mut filter_spectra[och * c + ic];
            for ky in 0..k {
                for kx in 0..k {
                    spec[ky * gc + kx] = Complex::new(wd[((och * c + ic) * k + ky) * k + kx], 0.0);
                }
            }
            fft2d(spec, gr, gc);
        }
    }

    let x = input.data();
    let mut out = Tensor::zeros(Shape::nchw(n, oc, oh, ow));
    let o = out.data_mut();

    let mut xspec = vec![Complex::zero(); gr * gc];
    let mut acc = vec![Complex::zero(); gr * gc];
    for b in 0..n {
        // Transform each input channel once per batch element.
        let mut channel_spectra = vec![vec![Complex::zero(); gr * gc]; c];
        for ic in 0..c {
            xspec.iter_mut().for_each(|v| *v = Complex::zero());
            let plane = &x[(b * c + ic) * h * w..(b * c + ic + 1) * h * w];
            for iy in 0..h {
                for ix in 0..w {
                    // Shift by pad so index 0 is the padded border.
                    xspec[(iy + params.pad) * gc + (ix + params.pad)] =
                        Complex::new(plane[iy * w + ix], 0.0);
                }
            }
            fft2d(&mut xspec, gr, gc);
            channel_spectra[ic].copy_from_slice(&xspec);
        }
        for och in 0..oc {
            acc.iter_mut().for_each(|v| *v = Complex::zero());
            for ic in 0..c {
                let fs = &filter_spectra[och * c + ic];
                let cs = &channel_spectra[ic];
                // Correlation: X(f) * conj(W(f)).
                for ((a, &xv), &wv) in acc.iter_mut().zip(cs.iter()).zip(fs.iter()) {
                    *a = a.add(xv.mul(wv.conj()));
                }
            }
            ifft2d(&mut acc, gr, gc);
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let orow = &mut o[((b * oc + och) * oh) * ow..((b * oc + och) * oh + oh) * ow];
            for oy in 0..oh {
                for ox in 0..ow {
                    orow[oy * ow + ox] = acc[(oy * params.stride) * gc + ox * params.stride].re + bias_v;
                }
            }
        }
    }
    Ok(out)
}

/// FLOP estimate for one FFT conv call (used by E6's model columns).
pub fn fft_conv_flops(
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    oc: usize,
    k: usize,
    pad: usize,
) -> u64 {
    let gr = (h + 2 * pad).next_power_of_two() as u64;
    let gc = (w + 2 * pad).next_power_of_two() as u64;
    let grid = gr * gc;
    let fft_cost = 5 * grid * (grid as f64).log2() as u64; // ~5N log N per 2-D FFT
    let n = n as u64;
    let c = c as u64;
    let oc = oc as u64;
    let _ = k;
    // filters: oc*c ffts; inputs: n*c ffts; outputs: n*oc iffts; pointwise: n*oc*c*grid*6
    (oc * c + n * c + n * oc) * fft_cost + n * oc * c * grid * 6
}

#[cfg(test)]
mod tests {
    use super::super::conv::conv2d_direct;
    use super::*;
    use crate::testutil::{Gen, XorShiftRng};

    #[test]
    fn matches_direct_small() {
        let mut rng = XorShiftRng::new(61);
        let x = Tensor::new(Shape::nchw(1, 1, 5, 5), Gen::tensor_data(&mut rng, 25)).unwrap();
        let w = Tensor::new(&[1, 1, 3, 3][..], Gen::tensor_data(&mut rng, 9)).unwrap();
        let p = Conv2dParams::new(1, 0);
        let yd = conv2d_direct(&x, &w, None, p).unwrap();
        let yf = conv2d_fft(&x, &w, None, p).unwrap();
        crate::testutil::assert_allclose(yf.data(), yd.data(), 1e-3, 1e-4);
    }

    #[test]
    fn matches_direct_property() {
        crate::testutil::check(25, 303, Gen::conv_shape, |s| {
            let mut rng = XorShiftRng::new((s.w * 131 + s.out_ch) as u64);
            let x = Tensor::new(
                Shape::nchw(s.batch, s.in_ch, s.h, s.w),
                Gen::tensor_data(&mut rng, s.batch * s.in_ch * s.h * s.w),
            )
            .unwrap();
            let w = Tensor::new(
                &[s.out_ch, s.in_ch, s.k, s.k][..],
                Gen::tensor_data(&mut rng, s.out_ch * s.in_ch * s.k * s.k),
            )
            .unwrap();
            let b = Tensor::new(&[s.out_ch][..], Gen::tensor_data(&mut rng, s.out_ch)).unwrap();
            let p = Conv2dParams::new(s.stride, s.pad);
            let yd = conv2d_direct(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            let yf = conv2d_fft(&x, &w, Some(&b), p).map_err(|e| e.to_string())?;
            for (i, (&a, &e)) in yf.data().iter().zip(yd.data()).enumerate() {
                if (a - e).abs() > 2e-3 + 1e-3 * e.abs() {
                    return Err(format!("mismatch at {i}: fft={a} direct={e} ({s:?})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn strided_fft_conv() {
        let mut rng = XorShiftRng::new(62);
        let x = Tensor::new(Shape::nchw(1, 2, 8, 8), Gen::tensor_data(&mut rng, 128)).unwrap();
        let w = Tensor::new(&[2, 2, 3, 3][..], Gen::tensor_data(&mut rng, 36)).unwrap();
        let p = Conv2dParams::new(2, 1);
        let yd = conv2d_direct(&x, &w, None, p).unwrap();
        let yf = conv2d_fft(&x, &w, None, p).unwrap();
        assert_eq!(yd.shape(), yf.shape());
        crate::testutil::assert_allclose(yf.data(), yd.data(), 1e-3, 1e-3);
    }

    #[test]
    fn flop_model_monotone_in_kernel_grid() {
        // FFT cost is flat in k (grid-dominated) while direct grows with k².
        let small = fft_conv_flops(1, 16, 32, 32, 16, 3, 1);
        let large = fft_conv_flops(1, 16, 32, 32, 16, 11, 5);
        // Larger pad -> larger grid, but same order of magnitude.
        assert!(large >= small);
        assert!(large < small * 8);
    }
}
