//! Packed int8 GEMM — the full-integer inner engine behind the
//! `*_i8i8_into` conv/dense kernels (ROADMAP item 2 follow-on: turn the
//! int8 footprint win into a latency win).
//!
//! Why integer GEMM beats the f32 kernels here: the f32 dense/GEMM inner
//! loops are serial dot products, and LLVM cannot vectorize an f32
//! reduction (FP addition is not associative, and this crate builds
//! without `-ffast-math`-style reassociation). Integer addition *is*
//! associative, so the canonical `acc += a[i] as i32 * b[i] as i32` zip
//! loop in [`dot_i8`] autovectorizes to widening-multiply/add lanes
//! (`pmaddwd` is baseline SSE2 on x86-64, `smlal` on NEON) — 8–16 MACs
//! per cycle where the f32 loop retires one fused multiply-add per
//! FP-latency chain.
//!
//! The weight side is pre-packed once at plan-compile time into
//! [`PackedI8`]: row-major dot-layout panels (`[rows, k_pad]`, each row
//! zero-padded to a multiple of 4) so every GEMM row reduction runs over
//! one contiguous, alignment-friendly slice with no tail conditionals in
//! the hot loop. The activation side is quantized per forward by the
//! plan (`compression::quantize_i8_into`) into the i8 arena, and the
//! i32 accumulator is brought back to f32 with one fused
//! `requant_scale(x_scale, w_scale)` multiply in the epilogue.

use crate::compression::ResidentI8;

use super::parallel::{Par, UnsafeSlice};
use super::Conv2dParams;

/// Largest reduction depth the i8×i8→i32 kernels accept: with worst-case
/// ±127 codes each MAC contributes ≤ 127² = 16129, so `i32::MAX / 16129`
/// ≈ 133 152 guarantees the accumulator cannot overflow. Every model
/// layer in sight is orders of magnitude below this (AlexNet fc6, the
/// largest layer in the paper's lineage, has k = 9216).
pub const MAX_GEMM_K: usize = 133_000;

/// Number of B rows processed per block in [`gemm_i8_i32`]: a 16-row
/// panel of k ≤ 1024 stays L1/L2-hot while the A row streams across it.
const JB: usize = 16;

/// A weight tensor packed for the integer GEMM: the symmetric-i8 codes of
/// a [`ResidentI8`], laid out as `rows` contiguous dot-panels of
/// `k_pad = k.next_multiple_of(4)` codes (tail zero-padded). `rows` is
/// the leading logical dim (out-channels for conv, out-features for
/// dense); `k` is the collapsed remainder (`in_ch·k·k` resp. `in`),
/// which is already the dot-product layout for both layer kinds — packing
/// is a pad-and-copy, not a transpose.
#[derive(Clone, Debug)]
pub struct PackedI8 {
    shape: Vec<usize>,
    rows: usize,
    k: usize,
    k_pad: usize,
    data: Vec<i8>,
    scale: f32,
}

impl PackedI8 {
    /// Pack resident codes into padded dot-panels. Panics if the
    /// reduction depth exceeds [`MAX_GEMM_K`] (i32 accumulator safety) —
    /// a compile-time (plan-build) event, never a per-forward one.
    pub fn pack(q: &ResidentI8) -> PackedI8 {
        let shape = q.dims().to_vec();
        assert!(!shape.is_empty() && shape[0] > 0, "packed weights need a leading dim");
        let rows = shape[0];
        let numel = q.numel();
        assert_eq!(numel % rows, 0, "ragged weight shape {shape:?}");
        let k = numel / rows;
        let k_pad = k.next_multiple_of(4);
        assert!(
            k_pad <= MAX_GEMM_K,
            "reduction depth {k_pad} exceeds i32-safe bound {MAX_GEMM_K}"
        );
        let mut data = vec![0i8; rows * k_pad];
        for r in 0..rows {
            data[r * k_pad..r * k_pad + k].copy_from_slice(&q.codes()[r * k..(r + 1) * k]);
        }
        PackedI8 { shape, rows, k, k_pad, data, scale: q.scale() }
    }

    /// Logical (unpacked) weight shape, e.g. `[oc, ic, k, k]`.
    pub fn dims(&self) -> &[usize] {
        &self.shape
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical reduction depth (codes per row before padding).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Padded panel stride (multiple of 4).
    pub fn k_pad(&self) -> usize {
        self.k_pad
    }

    /// Packed panels, `rows * k_pad` codes.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Resident size: one byte per packed code plus the f32 scale.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4
    }
}

/// Contiguous i8 dot product with i32 accumulation. The length-bounded
/// reslice lets the bounds checks hoist out of the loop, and the integer
/// reduction reassociates freely — this is the loop the autovectorizer
/// turns into widening multiply-add lanes.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = 0i32;
    for i in 0..n {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// Integer GEMM over pre-transposed panels:
/// `out[m, n] = A[m, k_pad] · Bᵀ` where `bt` holds `n` rows of `k_pad`
/// codes each (both operands row-major in dot layout). Blocked over `bt`
/// rows ([`JB`]) so a panel of B stays cache-hot while successive A rows
/// stream across it. Accumulation is exact i8×i8→i32 — no rounding
/// until the caller's requantization epilogue.
pub fn gemm_i8_i32(m: usize, n: usize, k_pad: usize, a: &[i8], bt: &[i8], out: &mut [i32]) {
    gemm_i8_i32_par(m, n, k_pad, a, bt, out, Par::serial());
}

/// [`gemm_i8_i32`] partitioned over `m`-panels: each chunk owns a
/// contiguous block of A rows (and the matching output rows) and runs
/// the full [`JB`]-blocked walk over the shared read-only B panel.
/// Every output element is one whole [`dot_i8`], so the result is
/// bitwise identical to serial at any thread count.
pub fn gemm_i8_i32_par(
    m: usize,
    n: usize,
    k_pad: usize,
    a: &[i8],
    bt: &[i8],
    out: &mut [i32],
    par: Par,
) {
    assert!(a.len() >= m * k_pad, "A panel too small");
    assert!(bt.len() >= n * k_pad, "B panel too small");
    assert!(out.len() >= m * n, "output too small");
    let ov = UnsafeSlice::new(&mut out[..m * n]);
    par.run_chunks(m, |i_lo, i_hi| {
        // SAFETY: each chunk owns the disjoint row band [i_lo, i_hi).
        let orows = unsafe { ov.slice(i_lo * n, i_hi * n) };
        for j0 in (0..n).step_by(JB) {
            let jmax = (j0 + JB).min(n);
            for i in i_lo..i_hi {
                let arow = &a[i * k_pad..(i + 1) * k_pad];
                let orow = &mut orows[(i - i_lo) * n..(i - i_lo + 1) * n];
                for j in j0..jmax {
                    orow[j] = dot_i8(arow, &bt[j * k_pad..(j + 1) * k_pad]);
                }
            }
        }
    });
}

/// i8 im2col in *transposed* (dot) layout: lowers one quantized image
/// `xq = [c, h, w]` into `out[cols, k_pad]` where each row is the full
/// receptive field of one output pixel, zero-padded to `k_pad`. Unlike
/// the f32 [`super::im2col_into`] (which emits `[c·k·k, cols]` for the
/// broadcast-row GEMM), the transposed layout makes each GEMM reduction
/// a contiguous slice pair for [`gemm_i8_i32`].
///
/// The buffer is fully zeroed first: padding cells and the per-row tail
/// must not leak stale codes when the plan reuses the scratch across
/// batch elements and layers.
pub fn im2col_i8_transposed(
    xq: &[i8],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    params: Conv2dParams,
    k_pad: usize,
    out: &mut [i8],
) {
    im2col_i8_transposed_par(xq, c, h, w, k, params, k_pad, out, Par::serial());
}

/// [`im2col_i8_transposed`] partitioned over output-pixel (patch-row)
/// blocks: each chunk zero-fills its own rows and then writes them, so
/// the buffer contents are identical to the serial lowering at any
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn im2col_i8_transposed_par(
    xq: &[i8],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    params: Conv2dParams,
    k_pad: usize,
    out: &mut [i8],
    par: Par,
) {
    debug_assert!(xq.len() >= c * h * w);
    let oh = (h + 2 * params.pad - k) / params.stride + 1;
    let ow = (w + 2 * params.pad - k) / params.stride + 1;
    let cols = oh * ow;
    assert!(k_pad >= c * k * k, "k_pad {k_pad} < patch size {}", c * k * k);
    assert!(out.len() >= cols * k_pad, "patch buffer too small");
    let ov = UnsafeSlice::new(&mut out[..cols * k_pad]);
    par.run_chunks(cols, |p_lo, p_hi| {
        // SAFETY: each chunk owns the disjoint patch rows [p_lo, p_hi).
        let orows = unsafe { ov.slice(p_lo * k_pad, p_hi * k_pad) };
        orows.fill(0);
        for p in p_lo..p_hi {
            let (oy, ox) = (p / ow, p % ow);
            let orow = &mut orows[(p - p_lo) * k_pad..(p - p_lo + 1) * k_pad];
            let x0 = ox * params.stride;
            // Clip the kernel window against the image once per pixel;
            // the surviving kx run is a contiguous copy.
            let kx_lo = params.pad.saturating_sub(x0);
            let kx_hi = k.min((w + params.pad).saturating_sub(x0));
            if kx_lo >= kx_hi {
                continue;
            }
            let ix0 = x0 + kx_lo - params.pad;
            for ic in 0..c {
                for ky in 0..k {
                    let iy = (oy * params.stride + ky) as isize - params.pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let x_row = ic * h * w + iy as usize * w;
                    let r0 = (ic * k + ky) * k;
                    orow[r0 + kx_lo..r0 + kx_hi]
                        .copy_from_slice(&xq[x_row + ix0..x_row + ix0 + (kx_hi - kx_lo)]);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::testutil::{Gen, XorShiftRng};

    fn packed_from(dims: &[usize], data: Vec<f32>) -> PackedI8 {
        let t = Tensor::new(dims, data).unwrap();
        PackedI8::pack(&ResidentI8::quantize(&t))
    }

    #[test]
    fn pack_pads_rows_to_multiple_of_four() {
        // [2, 9] weight (k=9) → k_pad=12, tails zero.
        let q = packed_from(&[2, 3, 3][..], (1..=18).map(|v| v as f32).collect());
        assert_eq!((q.rows(), q.k(), q.k_pad()), (2, 9, 12));
        assert_eq!(q.data().len(), 2 * 12);
        assert_eq!(q.bytes(), 2 * 12 + 4);
        for r in 0..2 {
            assert_eq!(&q.data()[r * 12 + 9..(r + 1) * 12], &[0, 0, 0]);
            // Unpadded prefix preserves the resident codes in order.
            let t = Tensor::new(&[2, 3, 3][..], (1..=18).map(|v| v as f32).collect()).unwrap();
            let res = ResidentI8::quantize(&t);
            assert_eq!(&q.data()[r * 12..r * 12 + 9], &res.codes()[r * 9..(r + 1) * 9]);
        }
        // Already-aligned k is untouched.
        let q4 = packed_from(&[3, 4][..], (1..=12).map(|v| v as f32).collect());
        assert_eq!((q4.k(), q4.k_pad()), (4, 4));
    }

    #[test]
    fn dot_i8_exact_and_saturating_codes() {
        let a = vec![127i8; 1000];
        let b = vec![-127i8; 1000];
        assert_eq!(dot_i8(&a, &b), -127 * 127 * 1000);
        assert_eq!(dot_i8(&[], &[]), 0);
        assert_eq!(dot_i8(&[3, -4, 5], &[2, 2, 2]), 8);
    }

    #[test]
    fn gemm_matches_scalar_reference() {
        let mut rng = XorShiftRng::new(314);
        let (m, n, k_pad) = (5, 13, 24);
        let a: Vec<i8> = (0..m * k_pad).map(|_| (rng.range_usize(0, 255) as i32 - 127) as i8).collect();
        let bt: Vec<i8> =
            (0..n * k_pad).map(|_| (rng.range_usize(0, 255) as i32 - 127) as i8).collect();
        let mut out = vec![i32::MIN; m * n];
        gemm_i8_i32(m, n, k_pad, &a, &bt, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for kk in 0..k_pad {
                    acc += a[i * k_pad + kk] as i64 * bt[j * k_pad + kk] as i64;
                }
                assert_eq!(out[i * n + j] as i64, acc, "({i},{j})");
            }
        }
    }

    #[test]
    fn transposed_im2col_matches_f32_lowering() {
        // Quantize with integer-valued activations so the i8 codes decode
        // exactly, then check every patch row against the f32 im2col
        // column for the same output pixel.
        let mut rng = XorShiftRng::new(99);
        let (c, h, w, k) = (2, 5, 6, 3);
        let data: Vec<f32> =
            (0..c * h * w).map(|_| (rng.range_usize(0, 255) as i32 - 127) as f32).collect();
        let x = Tensor::new(crate::tensor::Shape::nchw(1, c, h, w), data).unwrap();
        for params in [Conv2dParams::new(1, 1), Conv2dParams::new(2, 0), Conv2dParams::new(1, 2)] {
            let (oh, ow) = params.out_hw(h, w, k).unwrap();
            let cols = oh * ow;
            let rows = c * k * k;
            let k_pad = rows.next_multiple_of(4);
            let q = ResidentI8::quantize(&x);
            let mut patches_q = vec![i8::MIN; cols * k_pad + 7]; // poisoned + oversized
            im2col_i8_transposed(q.codes(), c, h, w, k, params, k_pad, &mut patches_q);
            let f = super::super::im2col(&x, 0, k, params).unwrap();
            let scale = q.scale();
            for col in 0..cols {
                for r in 0..rows {
                    let got = patches_q[col * k_pad + r] as f32 * scale;
                    let want = f.data()[r * cols + col];
                    assert!(
                        (got - want).abs() <= scale * 0.5 + 1e-6,
                        "col={col} r={r}: {got} vs {want} ({params:?})"
                    );
                }
                for r in rows..k_pad {
                    assert_eq!(patches_q[col * k_pad + r], 0, "tail must stay zero");
                }
            }
        }
    }

    #[test]
    fn gemm_end_to_end_equals_f32_conv_on_integer_data() {
        // Activations and weights are integers in [-127, 127] with the
        // max magnitude pinned at 127, so the symmetric scale is exactly
        // 1.0 and quantization is lossless. The integer pipeline (pack →
        // lower → gemm → requant) must then reproduce the f32 conv
        // exactly: every partial sum is an integer below 2^24.
        let mut rng = XorShiftRng::new(7);
        let (c, h, w, oc, k) = (3, 6, 6, 4, 3);
        let params = Conv2dParams::new(1, 1);
        let mut xd: Vec<f32> =
            (0..c * h * w).map(|_| (rng.range_usize(0, 255) as i32 - 127) as f32).collect();
        let mut wd: Vec<f32> =
            (0..oc * c * k * k).map(|_| (rng.range_usize(0, 255) as i32 - 127) as f32).collect();
        xd[0] = 127.0;
        wd[0] = 127.0;
        let x = Tensor::new(crate::tensor::Shape::nchw(1, c, h, w), xd).unwrap();
        let wt = Tensor::new(&[oc, c, k, k][..], wd).unwrap();
        let expect = super::super::conv2d_direct(&x, &wt, None, params).unwrap();

        let xq = ResidentI8::quantize(&x);
        let wq = PackedI8::pack(&ResidentI8::quantize(&wt));
        assert_eq!(xq.scale(), 1.0);
        assert_eq!(wq.scale(), 1.0);
        let (oh, ow) = params.out_hw(h, w, k).unwrap();
        let cols = oh * ow;
        let mut patches_q = vec![0i8; cols * wq.k_pad()];
        im2col_i8_transposed(xq.codes(), c, h, w, k, params, wq.k_pad(), &mut patches_q);
        let mut acc = vec![0i32; oc * cols];
        gemm_i8_i32(oc, cols, wq.k_pad(), wq.data(), &patches_q, &mut acc);
        let rs = crate::compression::requant_scale(xq.scale(), wq.scale());
        assert_eq!(rs, 1.0);
        for (i, (&ai, &ev)) in acc.iter().zip(expect.data()).enumerate() {
            assert_eq!(ai as f32 * rs, ev, "output {i}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds i32-safe bound")]
    fn pack_rejects_overflow_prone_depth() {
        let t = Tensor::zeros(&[1, MAX_GEMM_K + 4][..]);
        PackedI8::pack(&ResidentI8::quantize(&t));
    }
}
