//! 1-D convolution over [batch, channels, length] tensors.
//!
//! The paper's roadmap item 9 singles out NLP: "in the case of natural
//! language processing with convolutional neural networks one uses 1D
//! convolution instead of 2D", citing Zhang & LeCun's character-level
//! CNNs. The char-CNN zoo model and `examples/text_cnn.rs` run on this op.

use crate::tensor::{Shape, Tensor};

/// 1-D convolution hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv1dParams {
    pub stride: usize,
    pub pad: usize,
}

impl Default for Conv1dParams {
    fn default() -> Self {
        Conv1dParams { stride: 1, pad: 0 }
    }
}

impl Conv1dParams {
    pub fn out_len(&self, len: usize, k: usize) -> crate::Result<usize> {
        anyhow::ensure!(self.stride > 0, "stride must be positive");
        anyhow::ensure!(len + 2 * self.pad >= k, "kernel {k} larger than padded length");
        Ok((len + 2 * self.pad - k) / self.stride + 1)
    }
}

/// Cross-correlation over the last axis. Input `[n, c, l]`, weight
/// `[oc, c, k]`, output `[n, oc, out_len]`.
pub fn conv1d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv1dParams,
) -> crate::Result<Tensor> {
    anyhow::ensure!(input.shape().rank() == 3, "conv1d input must be [n,c,l], got {}", input.shape());
    anyhow::ensure!(weight.shape().rank() == 3, "conv1d weight must be [oc,c,k]");
    let n = input.shape().dim(0);
    let oc = weight.shape().dim(0);
    let ol = params.out_len(input.shape().dim(2), weight.shape().dim(2))?;
    let mut out = Tensor::zeros(Shape::new(&[n, oc, ol]));
    conv1d_into(input, weight, bias, params, &mut out)?;
    Ok(out)
}

/// [`conv1d`] into a preallocated `[n, oc, out_len]` tensor.
pub fn conv1d_into(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    params: Conv1dParams,
    out: &mut Tensor,
) -> crate::Result<()> {
    anyhow::ensure!(input.shape().rank() == 3, "conv1d input must be [n,c,l], got {}", input.shape());
    anyhow::ensure!(weight.shape().rank() == 3, "conv1d weight must be [oc,c,k]");
    let (n, c, l) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (oc, wc, k) = (weight.shape().dim(0), weight.shape().dim(1), weight.shape().dim(2));
    anyhow::ensure!(wc == c, "weight channels {wc} != input {c}");
    if let Some(b) = bias {
        anyhow::ensure!(b.numel() == oc, "bias size {} != {oc}", b.numel());
    }
    let ol = params.out_len(l, k)?;
    anyhow::ensure!(
        out.shape().dims() == [n, oc, ol],
        "conv1d out tensor is {}, expected [{n},{oc},{ol}]",
        out.shape()
    );
    let (x, wd) = (input.data(), weight.data());
    let o = out.data_mut();
    for b in 0..n {
        for och in 0..oc {
            let bias_v = bias.map_or(0.0, |bv| bv.data()[och]);
            let orow = &mut o[(b * oc + och) * ol..(b * oc + och + 1) * ol];
            for (oi, ov) in orow.iter_mut().enumerate() {
                let mut acc = bias_v;
                for ic in 0..c {
                    let xrow = &x[(b * c + ic) * l..(b * c + ic + 1) * l];
                    let wrow = &wd[(och * c + ic) * k..(och * c + ic + 1) * k];
                    for (ki, &wv) in wrow.iter().enumerate() {
                        let ix = (oi * params.stride + ki) as isize - params.pad as isize;
                        if ix >= 0 && (ix as usize) < l {
                            acc += xrow[ix as usize] * wv;
                        }
                    }
                }
                *ov = acc;
            }
        }
    }
    Ok(())
}

/// 1-D max pooling (char-CNN downsampling).
pub fn max_pool1d(input: &Tensor, k: usize, stride: usize) -> crate::Result<Tensor> {
    anyhow::ensure!(input.shape().rank() == 3, "pool1d input must be [n,c,l]");
    anyhow::ensure!(k > 0 && stride > 0, "window and stride must be positive");
    let (n, c, l) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    anyhow::ensure!(l >= k, "window {k} larger than length {l}");
    let ol = (l - k) / stride + 1;
    let mut out = Tensor::zeros(Shape::new(&[n, c, ol]));
    max_pool1d_into(input, k, stride, &mut out)?;
    Ok(out)
}

/// [`max_pool1d`] into a preallocated `[n, c, out_len]` tensor.
pub fn max_pool1d_into(input: &Tensor, k: usize, stride: usize, out: &mut Tensor) -> crate::Result<()> {
    anyhow::ensure!(input.shape().rank() == 3, "pool1d input must be [n,c,l]");
    anyhow::ensure!(k > 0 && stride > 0, "window and stride must be positive");
    let (n, c, l) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    anyhow::ensure!(l >= k, "window {k} larger than length {l}");
    let ol = (l - k) / stride + 1;
    anyhow::ensure!(
        out.shape().dims() == [n, c, ol],
        "pool1d out tensor is {}, expected [{n},{c},{ol}]",
        out.shape()
    );
    let x = input.data();
    let o = out.data_mut();
    for plane in 0..n * c {
        let xrow = &x[plane * l..(plane + 1) * l];
        let orow = &mut o[plane * ol..(plane + 1) * ol];
        for (oi, ov) in orow.iter_mut().enumerate() {
            let start = oi * stride;
            *ov = xrow[start..start + k].iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, XorShiftRng};

    #[test]
    fn known_smoothing_kernel() {
        let x = Tensor::new(&[1, 1, 4][..], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::new(&[1, 1, 2][..], vec![0.5, 0.5]).unwrap();
        let y = conv1d(&x, &w, None, Conv1dParams::default()).unwrap();
        assert_eq!(y.data(), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn padding_and_stride() {
        let x = Tensor::new(&[1, 1, 3][..], vec![1.0, 2.0, 3.0]).unwrap();
        let w = Tensor::new(&[1, 1, 3][..], vec![1.0, 1.0, 1.0]).unwrap();
        let y = conv1d(&x, &w, None, Conv1dParams { stride: 2, pad: 1 }).unwrap();
        // Windows at offsets -1 and 1: [_,1,2]=3, [2,3,_]=5
        assert_eq!(y.data(), &[3.0, 5.0]);
    }

    #[test]
    fn channels_accumulate_with_bias() {
        let x = Tensor::new(&[1, 2, 2][..], vec![1.0, 2.0, 10.0, 20.0]).unwrap();
        let w = Tensor::new(&[1, 2, 1][..], vec![1.0, 0.1]).unwrap();
        let b = Tensor::new(&[1][..], vec![0.5]).unwrap();
        let y = conv1d(&x, &w, Some(&b), Conv1dParams::default()).unwrap();
        assert_eq!(y.data(), &[2.5, 4.5]);
    }

    #[test]
    fn matches_conv2d_on_height1_property() {
        // conv1d must equal conv2d with h=1 kernels/inputs.
        crate::testutil::check(
            30,
            404,
            |rng| {
                (
                    rng.range_usize(1, 3),
                    rng.range_usize(1, 4),
                    rng.range_usize(1, 4),
                    rng.range_usize(3, 16),
                    *rng.choose(&[1usize, 3, 5]),
                    rng.range_usize(1, 3),
                    rng.next_u64(),
                )
            },
            |&(n, c, oc, l, k, stride, seed)| {
                if l < k {
                    return Ok(());
                }
                let mut rng = XorShiftRng::new(seed);
                let xd = Gen::tensor_data(&mut rng, n * c * l);
                let wd = Gen::tensor_data(&mut rng, oc * c * k);
                let x1 = Tensor::new(&[n, c, l][..], xd.clone()).unwrap();
                let w1 = Tensor::new(&[oc, c, k][..], wd.clone()).unwrap();
                let y1 = conv1d(&x1, &w1, None, Conv1dParams { stride, pad: 0 })
                    .map_err(|e| e.to_string())?;

                // 2-D equivalent: [n,c,1,l] with [oc,c,1,k] kernel... our 2-D
                // op requires square kernels, so emulate with k x k kernel of
                // zeros except the middle row when k allows. Instead compare
                // against a simple shift-and-add reference here.
                let ol = (l - k) / stride + 1;
                for b in 0..n {
                    for och in 0..oc {
                        for oi in 0..ol {
                            let mut acc = 0.0f32;
                            for ic in 0..c {
                                for ki in 0..k {
                                    acc += xd[(b * c + ic) * l + oi * stride + ki]
                                        * wd[(och * c + ic) * k + ki];
                                }
                            }
                            let got = y1.at(&[b, och, oi]);
                            if (got - acc).abs() > 1e-4 + 1e-4 * acc.abs() {
                                return Err(format!("mismatch at ({b},{och},{oi}): {got} vs {acc}"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn max_pool1d_known() {
        let x = Tensor::new(&[1, 1, 6][..], vec![1.0, 5.0, 2.0, 8.0, 3.0, 0.0]).unwrap();
        let y = max_pool1d(&x, 3, 3).unwrap();
        assert_eq!(y.data(), &[5.0, 8.0]);
    }

    #[test]
    fn errors_on_bad_shapes() {
        let x = Tensor::zeros(&[1, 2, 4][..]);
        let w = Tensor::zeros(&[1, 3, 2][..]);
        assert!(conv1d(&x, &w, None, Conv1dParams::default()).is_err());
        assert!(max_pool1d(&x, 5, 1).is_err());
    }
}
