//! The coordinator: per-model batcher workers in front of the engine
//! pool, with end-to-end latency metrics, SLO accounting and submit-time
//! admission control.
//!
//! A model replicated on k shards gets **k batcher workers** sharing one
//! submission queue: while one worker's batch executes on its routed
//! replica, a sibling collects the next batch — so a single hot model can
//! keep every replica busy. With k = 1 this degenerates to the original
//! one-worker-per-model loop.
//!
//! Batches **stream** into the engine pool: a worker submits each formed
//! batch with `PoolHandle::infer_async` and hands the in-flight ticket to
//! the model's completion thread, so collection never blocks on
//! execution — consecutive batches from one worker overlap inside the
//! routed shard's pipeline window, and backpressure surfaces as the typed
//! [`Overloaded`] error when that window is full.

use super::batcher::{Batcher, BatcherConfig, Pending, PreparedBatch};
use super::NIELSEN_SLO_MICROS;
use crate::metrics::{Histogram, ServingStats};
use crate::model::{Architecture, Manifest, ModelFiles};
use crate::nn::CostModel;
use crate::runtime::{EngineHandle, ModelInfo, Overloaded, PoolHandle, PoolTicket, Shed, SwapReport};
use crate::selector::{Candidate, Context, MetaModel};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorConfig {
    /// Per-model dynamic-batching parameters (`queue_cap` doubles as the
    /// submit-time admission bound per model).
    pub batcher: BatcherConfig,
}

/// Per-model serving objective: a relative priority (feeds the shed
/// policy) and an optional per-request deadline (feeds degraded-mode
/// routing). Set via [`Coordinator::set_slo`] or the CLI's
/// `--slo model=prio:deadline_ms` flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slo {
    /// Relative importance; **higher sheds later**. Models default to 0.
    pub priority: usize,
    /// End-to-end latency deadline for one request. `None`: no deadline,
    /// degraded-mode routing never engages for this model.
    pub deadline: Option<Duration>,
}

impl Default for Slo {
    fn default() -> Slo {
        Slo { priority: 0, deadline: None }
    }
}

impl Slo {
    /// Parse one CLI SLO spec: `model=prio` or `model=prio:deadline_ms`
    /// (a 0 ms deadline means "no deadline").
    pub fn parse_spec(spec: &str) -> crate::Result<(String, Slo)> {
        let (model, rest) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad SLO spec `{spec}`: want model=prio[:deadline_ms]"))?;
        let model = model.trim();
        anyhow::ensure!(!model.is_empty(), "bad SLO spec `{spec}`: empty model id");
        let (prio, deadline) = match rest.split_once(':') {
            Some((p, d)) => (p, Some(d)),
            None => (rest, None),
        };
        let priority = prio
            .trim()
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad SLO spec `{spec}`: priority `{prio}` not a number"))?;
        let deadline = match deadline {
            None => None,
            Some(d) => {
                let ms = d.trim().parse::<u64>().map_err(|_| {
                    anyhow::anyhow!("bad SLO spec `{spec}`: deadline `{d}` not a number (ms)")
                })?;
                (ms > 0).then(|| Duration::from_millis(ms))
            }
        };
        Ok((model.to_string(), Slo { priority, deadline }))
    }
}

/// Pool admission saturation at which the lowest-priority traffic
/// starts shedding; higher priorities shed at graduated thresholds
/// between this and 1.0 (see [`should_shed`]).
const SHED_START: f64 = 0.75;

/// EWMA weight for each new queue-delay observation.
const QUEUE_DELAY_ALPHA: f64 = 0.3;

/// The pure SLO-shed policy: should a request for a model at `priority`
/// be shed, given the distinct priorities of every served model and the
/// pool's admission saturation (`inflight` of `capacity`)?
///
/// Shedding is **strictly lowest-priority-first**: the distinct served
/// priorities are ranked ascending, the lowest rank sheds once
/// saturation reaches [`SHED_START`], each higher rank sheds at a
/// proportionally higher threshold, and the top rank never sheds. With
/// uniform priorities (every model equal — the default) nothing sheds
/// and admission behaves exactly as before this policy existed.
pub fn should_shed(
    priority: usize,
    served_priorities: &[usize],
    inflight: usize,
    capacity: usize,
) -> bool {
    if capacity == 0 {
        return false;
    }
    let mut ranks: Vec<usize> = served_priorities.to_vec();
    ranks.sort_unstable();
    ranks.dedup();
    let n = ranks.len();
    if n <= 1 {
        return false;
    }
    let Some(rank) = ranks.iter().position(|&p| p == priority) else {
        return false;
    };
    if rank == n - 1 {
        return false; // the top priority is never shed
    }
    let saturation = inflight as f64 / capacity as f64;
    saturation >= SHED_START + (1.0 - SHED_START) * rank as f64 / (n - 1) as f64
}

/// The result of one request.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// Output row for this request (e.g. class probabilities).
    pub output: Tensor,
    /// Predicted class (argmax) for classifier models.
    pub predicted: usize,
    /// End-to-end latency observed by the coordinator.
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Engine-pool shard that executed the batch.
    pub shard: usize,
    /// Index of the chosen replica within the model's owner set (0 for an
    /// unreplicated model).
    pub replica: usize,
    /// Pipeline-window occupancy on the executing shard when this
    /// request's batch took its slot (1 = the batch had the shard's
    /// pipeline to itself).
    pub window: usize,
    /// Model that actually served this request (differs from the
    /// requested model when degraded-mode routing substituted a cheaper
    /// ladder model).
    pub model: String,
    /// The originally requested model when this answer was served
    /// degraded; `None` for a normal answer.
    pub degraded_from: Option<String>,
}

/// One streamed batch in flight: the formed batch plus its pool ticket.
/// Collect workers produce these; the model's completion thread waits and
/// scatters, so collection never blocks on execution.
struct FlushJob {
    prepared: PreparedBatch,
    ticket: PoolTicket,
}

struct ModelWorker {
    tx: mpsc::Sender<Pending>,
    /// Behind a mutex so a hot-swap ([`Coordinator::update_model`]) can
    /// refresh it while clients submit through `&self`.
    info: Mutex<ModelInfo>,
    /// Effective batcher max batch (clamped to the served version's
    /// largest executable batch at spawn). A hot-swap must not install a
    /// version that cannot execute batches this large.
    max_batch: usize,
    /// Requests submitted but not yet picked up by a batcher worker —
    /// the submit-time admission-control window (shared across workers).
    depth: Arc<AtomicUsize>,
    /// The batcher worker threads (one per replica at serve time), joined
    /// on retire so in-flight work drains before the model is unloaded
    /// from its owner set.
    joins: Vec<std::thread::JoinHandle<()>>,
    /// The served architecture (from the serve-time manifest), for the
    /// degraded-mode compatibility check and plan-cost estimate.
    arch: Option<Architecture>,
    /// The model's serving objective ([`Coordinator::set_slo`]).
    slo: Mutex<Slo>,
    /// Cached batch-1 forward estimate (microseconds) from the plan cost
    /// model; computed on first use, so only deadline-bearing models pay
    /// for the cost model's one-time calibration.
    est_forward_us: Mutex<Option<f64>>,
}

struct Shared {
    latency_hist: Mutex<Histogram>,
    batch_sizes: Mutex<Vec<usize>>,
    requests: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    degraded: AtomicU64,
    batches: AtomicU64,
    started: Instant,
    /// Per-model EWMA of observed queue delay (end-to-end latency minus
    /// the execute phase, microseconds): the measured term the
    /// degraded-mode predictor adds to the plan-cost forward estimate.
    queue_delay_us: Mutex<BTreeMap<String, f64>>,
    /// Test hook: a forced (inflight, capacity) saturation signal for
    /// the shed policy, in place of sampling the pool.
    saturation_override: Mutex<Option<(usize, usize)>>,
}

/// Multi-model serving coordinator over an engine pool.
///
/// One batcher worker per model replica coalesces requests into batches
/// and flushes them through the [`PoolHandle`], which routes each batch
/// to one replica of the model's owner set (power-of-two-choices on
/// outstanding requests). Rejections — at submit time when a model's
/// queue is at `queue_cap`, or downstream when the routed shard is
/// saturated — surface as typed [`Overloaded`] errors.
pub struct Coordinator {
    pool: PoolHandle,
    config: CoordinatorConfig,
    workers: BTreeMap<String, ModelWorker>,
    shared: Arc<Shared>,
}

impl Coordinator {
    /// Create a coordinator over a single engine (wrapped as a one-shard
    /// pool). Kept for small deployments and existing call sites; use
    /// [`Coordinator::over_pool`] to scale out.
    pub fn new(engine: EngineHandle, config: CoordinatorConfig) -> Coordinator {
        Coordinator::over_pool(PoolHandle::single(engine), config)
    }

    /// Create a coordinator over an engine pool.
    pub fn over_pool(pool: PoolHandle, config: CoordinatorConfig) -> Coordinator {
        Coordinator {
            pool,
            config,
            workers: BTreeMap::new(),
            shared: Arc::new(Shared {
                latency_hist: Mutex::new(Histogram::new()),
                batch_sizes: Mutex::new(Vec::new()),
                requests: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                degraded: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                started: Instant::now(),
                queue_delay_us: Mutex::new(BTreeMap::new()),
                saturation_override: Mutex::new(None),
            }),
        }
    }

    /// Load a model from a directory (placed onto the pool's default
    /// replica count by the placement policy) and start one batcher
    /// worker per replica.
    pub fn serve_model(&mut self, dir: impl Into<std::path::PathBuf>) -> crate::Result<ModelInfo> {
        let dir = dir.into();
        let arch = Manifest::load(&ModelFiles::new(&dir).manifest()).map(|m| m.arch).ok();
        let info = self.pool.load(dir)?;
        self.start_workers(info, arch)
    }

    /// Like [`Coordinator::serve_model`], but with an explicit per-model
    /// replica count (clamped to the pool's shard count).
    pub fn serve_model_replicated(
        &mut self,
        dir: impl Into<std::path::PathBuf>,
        replicas: usize,
    ) -> crate::Result<ModelInfo> {
        let dir = dir.into();
        let arch = Manifest::load(&ModelFiles::new(&dir).manifest()).map(|m| m.arch).ok();
        let info = self.pool.load_replicated(dir, replicas)?;
        self.start_workers(info, arch)
    }

    /// Spawn the loaded model's batcher workers (one per replica, all
    /// draining one shared submission queue) and register the worker set.
    /// `arch` (the serve-time manifest architecture, when readable)
    /// powers the SLO layer's plan-cost estimates and degraded-mode
    /// compatibility checks.
    fn start_workers(
        &mut self,
        info: ModelInfo,
        arch: Option<Architecture>,
    ) -> crate::Result<ModelInfo> {
        let id = info.id.clone();

        // Batch cap: don't exceed the largest AOT batch.
        let mut cfg = self.config.batcher;
        if let Some(&max_aot) = info.batches.iter().max() {
            cfg.max_batch = cfg.max_batch.min(max_aot);
        }

        let (tx, rx) = mpsc::channel::<Pending>();
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicUsize::new(0));
        let workers = self.pool.replica_count(&id).max(1);
        // Idle-poll bound for the collect phase. A lone worker keeps the
        // original lazy 50 ms poll; sibling workers must wake fast, since
        // a worker holding the shared receiver in `recv_timeout` blocks a
        // sibling whose local batch has hit its flush deadline.
        let idle_poll = if workers == 1 {
            Duration::from_millis(50)
        } else {
            cfg.max_delay.clamp(Duration::from_millis(1), Duration::from_millis(50))
        };
        // A lone worker may greedily drain the whole channel into its
        // batcher (the original behavior). Sibling workers stop at one
        // full batch, leaving the rest of a burst in the channel for the
        // other replicas' workers to pick up — otherwise the first worker
        // to take the lock would swallow the burst and serialize it onto
        // one replica.
        let greedy_cap = if workers == 1 { usize::MAX } else { cfg.max_batch };
        // The streaming seam: collect workers push (batch, ticket) jobs
        // here; one completion thread per model waits tickets out and
        // scatters replies, so the collect side never blocks on execution.
        let (done_tx, done_rx) = mpsc::channel::<FlushJob>();
        let mut joins = Vec::with_capacity(workers + 1);
        for w in 0..workers {
            let pool = self.pool.clone();
            let shared = self.shared.clone();
            let model_id = id.clone();
            let worker_depth = depth.clone();
            let worker_rx = rx.clone();
            let worker_done = done_tx.clone();
            let shard = info.shard;
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dlk-batcher-{id}-r{w}"))
                    .spawn(move || {
                        batcher_main(
                            worker_rx,
                            cfg,
                            idle_poll,
                            greedy_cap,
                            pool,
                            model_id,
                            shard,
                            worker_depth,
                            shared,
                            worker_done,
                        )
                    })
                    .map_err(|e| anyhow::anyhow!("spawning batcher: {e}"))?,
            );
        }
        // `done_tx` clones live only in the collect workers: when the last
        // one exits (retire drops the submission channel), the job channel
        // closes and the completion thread drains what's left and follows.
        // Joined last in `retire_model`, so retire still means "every reply
        // delivered before the unload".
        drop(done_tx);
        joins.push(
            std::thread::Builder::new()
                .name(format!("dlk-completer-{id}"))
                .spawn(move || completion_main(done_rx))
                .map_err(|e| anyhow::anyhow!("spawning completion thread: {e}"))?,
        );

        self.workers.insert(
            id,
            ModelWorker {
                tx,
                info: Mutex::new(info.clone()),
                max_batch: cfg.max_batch,
                depth,
                joins,
                arch,
                slo: Mutex::new(Slo::default()),
                est_forward_us: Mutex::new(None),
            },
        );
        Ok(info)
    }

    /// Set a served model's serving objective (priority + optional
    /// deadline). Takes effect for the next submission.
    pub fn set_slo(&self, id: &str, slo: Slo) -> crate::Result<()> {
        let worker = self
            .workers
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("model `{id}` is not being served"))?;
        *worker.slo.lock().unwrap() = slo;
        Ok(())
    }

    /// A served model's current serving objective.
    pub fn slo(&self, id: &str) -> Option<Slo> {
        self.workers.get(id).map(|w| *w.slo.lock().unwrap())
    }

    /// Hot-swap a served model to a new version directory while it keeps
    /// serving, across its **whole owner set**. Guarantees: **no request
    /// is ever failed by the update**; batches already submitted to a
    /// replica's shard complete on the old version (each shard's FIFO
    /// drains them ahead of its swap); requests submitted after this call
    /// returns run on the new version everywhere. Mid-rollout, replicas
    /// may briefly serve mixed versions (the swap walks the owner set in
    /// ascending shard order — see `PoolHandle::swap` for the ordering
    /// contract), and requests still coalescing in the model's batchers
    /// when a swap lands may flush to either side of it — version-
    /// consistent cutover for those would require pausing the batchers,
    /// which this path deliberately does not do. The model's batcher
    /// workers, queue and owner-set placement all survive the swap.
    /// Blocks until every replica has drained and replaced.
    pub fn update_model(
        &self,
        id: &str,
        dir: impl Into<std::path::PathBuf>,
    ) -> crate::Result<SwapReport> {
        let worker = self
            .workers
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("model `{id}` is not being served"))?;
        let dir = dir.into();
        // Refuse before touching the pool: swapping a directory whose
        // manifest names a different model would replace the wrong one.
        let manifest = Manifest::load(&ModelFiles::new(&dir).manifest())?;
        anyhow::ensure!(
            manifest.id == id,
            "update of `{id}` rejected: directory manifest says `{}`",
            manifest.id
        );
        // The running batcher's max batch was baked in at serve time; a
        // version that cannot execute batches that large would make every
        // oversized flush fail, breaking the zero-failed-requests
        // guarantee. Reject the update instead (retire + re-serve to
        // shrink the batcher).
        let new_max = manifest
            .aot_batches
            .iter()
            .max()
            .copied()
            // Weights-only packages run on the CPU default ladder.
            .unwrap_or(*crate::runtime::CpuModel::DEFAULT_BATCHES.last().unwrap());
        anyhow::ensure!(
            new_max >= worker.max_batch,
            "update of `{id}` rejected: new version's largest executable batch {new_max} is \
             below the running batcher's max batch {}; retire and re-serve to shrink it",
            worker.max_batch
        );
        let report = self.pool.swap(dir)?;
        *worker.info.lock().unwrap() = report.info.clone();
        Ok(report)
    }

    /// Stop serving a model: closes its queue, waits for every batcher
    /// worker to drain in-flight work, then unloads from its whole owner
    /// set (the model keeps its per-shard affinity for a later reload).
    pub fn retire_model(&mut self, id: &str) -> crate::Result<()> {
        let ModelWorker { tx, joins, .. } = self
            .workers
            .remove(id)
            .ok_or_else(|| anyhow::anyhow!("model `{id}` is not being served"))?;
        drop(tx); // closes the channel; workers drain remaining work
        for join in joins {
            let _ = join.join(); // drain must finish before the unload below
        }
        self.pool.unload(id)
    }

    /// Models currently served (point snapshots; a concurrent
    /// [`Coordinator::update_model`] may bump versions).
    pub fn served_models(&self) -> Vec<ModelInfo> {
        self.workers.values().map(|w| w.info.lock().unwrap().clone()).collect()
    }

    /// Submit one input (no batch dimension) and wait for its result.
    pub fn infer(&self, model_id: &str, input: Tensor) -> crate::Result<RequestResult> {
        self.submit(model_id, input)?.wait()
    }

    /// Submit asynchronously; returns a ticket to wait on. Admission
    /// control happens here: once `queue_cap` submissions are waiting to
    /// be picked up by the model's batcher workers, further submissions
    /// are rejected with a typed [`Overloaded`] error instead of queueing
    /// without bound. (Each of the model's k batcher workers also caps
    /// its internal queue at `queue_cap`, so a model holds at most
    /// ~(k+1)×`queue_cap` unserved requests across both stages — ~2× for
    /// an unreplicated model.)
    pub fn submit(&self, model_id: &str, input: Tensor) -> crate::Result<Ticket> {
        let preferred = self
            .workers
            .get(model_id)
            .ok_or_else(|| anyhow::anyhow!("model `{model_id}` is not being served"))?;
        let slo = *preferred.slo.lock().unwrap();
        // SLO shed: when the pool's admission windows approach
        // saturation, lower-priority traffic is turned away (typed
        // [`Shed`]) before it can queue behind higher-priority work.
        // Only engages when served models actually differ in priority,
        // so an unconfigured deployment admits exactly as before.
        let (inflight, capacity) = self.saturation_signal();
        if should_shed(slo.priority, &self.served_priorities(), inflight, capacity) {
            self.shared.shed.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(Shed {
                model: model_id.to_string(),
                priority: slo.priority,
                saturation_pct: if capacity == 0 { 0 } else { inflight * 100 / capacity },
            }));
        }
        // Deadline-driven degraded mode: when the preferred model's
        // predicted latency (plan-cost forward estimate + observed queue
        // delay) busts its deadline, answer with a cheaper compatible
        // ladder model the selector prices within the deadline.
        let (serve_id, degraded_from) = match slo.deadline {
            Some(deadline) => match self.pick_degraded(model_id, preferred, deadline) {
                Some(sub) => {
                    self.shared.degraded.fetch_add(1, Ordering::Relaxed);
                    (sub, Some(model_id.to_string()))
                }
                None => (model_id.to_string(), None),
            },
            None => (model_id.to_string(), None),
        };
        let worker = self
            .workers
            .get(&serve_id)
            .ok_or_else(|| anyhow::anyhow!("model `{serve_id}` is not being served"))?;
        // Atomic admission: increment first, back out on overflow, so
        // concurrent submitters can never admit past `queue_cap`.
        let prev = worker.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.config.batcher.queue_cap {
            worker.depth.fetch_sub(1, Ordering::AcqRel);
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            // Report the model's current primary shard; the serve-time
            // snapshot may be stale after a replica shrink.
            let shard = self
                .pool
                .shard_of(&serve_id)
                .unwrap_or_else(|| worker.info.lock().unwrap().shard);
            return Err(anyhow::Error::new(Overloaded {
                model: serve_id,
                shard,
                queue_cap: self.config.batcher.queue_cap,
            }));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let started = Instant::now();
        if worker.tx.send(Pending { input, enqueued: started, reply: reply_tx }).is_err() {
            worker.depth.fetch_sub(1, Ordering::AcqRel);
            return Err(anyhow::anyhow!("batcher for `{serve_id}` is gone"));
        }
        self.shared.requests.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket {
            reply: reply_rx,
            started,
            shared: self.shared.clone(),
            model: serve_id,
            degraded_from,
        })
    }

    /// The admission-saturation signal the shed policy keys on (the test
    /// override when set, else the pool's live counters).
    fn saturation_signal(&self) -> (usize, usize) {
        if let Some(forced) = *self.shared.saturation_override.lock().unwrap() {
            return forced;
        }
        self.pool.saturation()
    }

    /// Every served model's current priority (duplicates fine — the shed
    /// policy ranks distinct values).
    fn served_priorities(&self) -> Vec<usize> {
        self.workers.values().map(|w| w.slo.lock().unwrap().priority).collect()
    }

    /// The model's batch-1 forward estimate (microseconds) from the
    /// calibrated plan cost model, computed on first use and cached.
    fn est_forward_us(&self, worker: &ModelWorker) -> Option<f64> {
        let mut cached = worker.est_forward_us.lock().unwrap();
        if cached.is_none() {
            let arch = worker.arch.as_ref()?;
            *cached = CostModel::global().estimate_forward_us(arch, 1).ok();
        }
        *cached
    }

    /// A model's observed queue-delay EWMA (microseconds; 0 until the
    /// first completion — an idle deployment never predicts a miss).
    fn queue_delay_us(&self, id: &str) -> f64 {
        self.shared.queue_delay_us.lock().unwrap().get(id).copied().unwrap_or(0.0)
    }

    /// Degraded-mode pick for one submission: `Some(substitute)` when
    /// the preferred model's predicted latency busts `deadline` AND a
    /// strictly cheaper served model with the same input shape and class
    /// count is predicted to meet it (the selector prices the ladder
    /// with `deadline` as its latency budget). `None` otherwise —
    /// degraded mode is best-effort, so a predicted miss without a
    /// viable fallback still serves the preferred model.
    fn pick_degraded(&self, id: &str, preferred: &ModelWorker, deadline: Duration) -> Option<String> {
        let deadline_us = deadline.as_micros() as f64;
        let preferred_est = self.est_forward_us(preferred)?;
        if preferred_est + self.queue_delay_us(id) <= deadline_us {
            return None;
        }
        let arch = preferred.arch.as_ref()?;
        let classes = arch.num_classes().ok()?;
        let mut candidates = Vec::new();
        for (other_id, other) in &self.workers {
            if other_id == id {
                continue;
            }
            let Some(other_arch) = other.arch.as_ref() else { continue };
            if other_arch.input != arch.input || other_arch.num_classes().ok() != Some(classes) {
                continue;
            }
            let Some(est) = self.est_forward_us(other) else { continue };
            if est >= preferred_est {
                continue; // the ladder only steps down in cost
            }
            let predicted = est + self.queue_delay_us(other_id);
            candidates.push(Candidate {
                id: other_id.clone(),
                location_affinity: BTreeMap::new(),
                peak_hours: Vec::new(),
                infer_latency: Duration::from_micros(predicted.round() as u64),
                load_latency: Duration::ZERO,
                resident: true,
            });
        }
        let ctx = Context { latency_budget: deadline, ..Default::default() };
        MetaModel::default().select(&ctx, &candidates).map(|r| r.id)
    }

    /// Test hook: force the (inflight, capacity) saturation signal the
    /// shed policy sees, instead of sampling the pool.
    #[doc(hidden)]
    pub fn debug_force_saturation(&self, forced: Option<(usize, usize)>) {
        *self.shared.saturation_override.lock().unwrap() = forced;
    }

    /// Test hook: seed a model's observed queue-delay EWMA directly.
    #[doc(hidden)]
    pub fn debug_set_queue_delay(&self, id: &str, us: f64) {
        self.shared.queue_delay_us.lock().unwrap().insert(id.to_string(), us);
    }

    /// Serving statistics snapshot.
    pub fn stats(&self) -> ServingStats {
        let hist = self.shared.latency_hist.lock().unwrap();
        let batch_sizes = self.shared.batch_sizes.lock().unwrap();
        let requests = self.shared.requests.load(Ordering::Relaxed);
        let elapsed = self.shared.started.elapsed().as_secs_f64();
        ServingStats {
            requests,
            batches: self.shared.batches.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            degraded: self.shared.degraded.load(Ordering::Relaxed),
            p50_us: hist.quantile(0.5),
            p95_us: hist.quantile(0.95),
            p99_us: hist.quantile(0.99),
            max_us: hist.max(),
            mean_batch_size: if batch_sizes.is_empty() {
                0.0
            } else {
                batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len() as f64
            },
            throughput_rps: if elapsed > 0.0 { hist.count() as f64 / elapsed } else { 0.0 },
            slo_attainment: hist.fraction_under(NIELSEN_SLO_MICROS),
        }
    }

    /// Access to the underlying engine pool.
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }
}

/// A pending request handle.
pub struct Ticket {
    reply: mpsc::Receiver<crate::Result<(Tensor, super::batcher::BatchMeta)>>,
    started: Instant,
    shared: Arc<Shared>,
    /// Model actually serving this request (the degraded substitute when
    /// one was picked).
    model: String,
    /// Originally requested model when served degraded.
    degraded_from: Option<String>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<RequestResult> {
        let result = self
            .reply
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))?;
        let latency = self.started.elapsed();
        match result {
            Ok((output, meta)) => {
                self.shared
                    .latency_hist
                    .lock()
                    .unwrap()
                    .record(latency.as_micros() as u64);
                self.shared.batch_sizes.lock().unwrap().push(meta.batch_size);
                // Everything but the execute phase is queueing in the
                // wide sense (submit queue, batch window, pipeline
                // wait): feed the per-model EWMA the degraded-mode
                // predictor adds to the plan-cost forward estimate.
                let delay_us = (latency.as_micros() as u64).saturating_sub(meta.exec_micros);
                {
                    let mut delays = self.shared.queue_delay_us.lock().unwrap();
                    let ewma = delays.entry(self.model.clone()).or_insert(0.0);
                    *ewma = (1.0 - QUEUE_DELAY_ALPHA) * *ewma + QUEUE_DELAY_ALPHA * delay_us as f64;
                }
                let predicted = output.argmax();
                Ok(RequestResult {
                    output,
                    predicted,
                    latency,
                    batch_size: meta.batch_size,
                    shard: meta.shard,
                    replica: meta.replica,
                    window: meta.window,
                    model: self.model,
                    degraded_from: self.degraded_from,
                })
            }
            Err(e) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }
}

/// Batcher worker loop. Each served model runs one of these per replica;
/// the workers share the submission channel behind a mutex. A worker
/// holds the channel lock only while *collecting* (so at most one worker
/// coalesces arrivals at a time) and releases it to *flush*. A flush is a
/// **streaming submit**: the formed batch enters the routed shard's
/// pipeline window via `infer_async` and the in-flight ticket goes to the
/// model's completion thread — this worker immediately returns to
/// collecting, so consecutive batches overlap inside the shard's window
/// and a single worker keeps its replica's pipeline full. `shard` is the
/// model's primary shard, reported in queue-overflow rejections.
#[allow(clippy::too_many_arguments)]
fn batcher_main(
    rx: Arc<Mutex<mpsc::Receiver<Pending>>>,
    cfg: BatcherConfig,
    idle_poll: Duration,
    greedy_cap: usize,
    pool: PoolHandle,
    model_id: String,
    shard: usize,
    depth: Arc<AtomicUsize>,
    shared: Arc<Shared>,
    done: mpsc::Sender<FlushJob>,
) {
    // Stream one formed batch toward execution. Pre-admission failures
    // (unknown model, typed Overloaded from a full pipeline window) resolve
    // the whole batch immediately; an admitted batch resolves later on the
    // completion thread. If the completion thread is already gone (only
    // possible once serving is torn down), fall back to waiting inline so
    // no reply is ever dropped.
    let flush_streaming = |batcher: &mut Batcher| {
        let Some(prepared) = batcher.take(Instant::now()) else { return };
        shared.batches.fetch_add(1, Ordering::Relaxed);
        match pool.infer_async(&model_id, prepared.input().clone()) {
            Ok(ticket) => {
                if let Err(mpsc::SendError(job)) = done.send(FlushJob { prepared, ticket }) {
                    let result = job.ticket.wait();
                    Batcher::scatter(job.prepared, result);
                }
            }
            Err(e) => Batcher::scatter(prepared, Err(e)),
        }
    };
    let mut batcher = Batcher::new(cfg);
    loop {
        // Collect phase, under the shared receiver lock.
        let disconnected = {
            let rx = rx.lock().unwrap();
            let now = Instant::now();
            let timeout = batcher.next_deadline(now).unwrap_or(idle_poll);
            match rx.recv_timeout(timeout) {
                Ok(pending) => {
                    depth.fetch_sub(1, Ordering::AcqRel);
                    // Rejections are counted once, in `Ticket::wait`, when
                    // the error reaches the client. `shard` is the
                    // serve-time primary — a diagnostic-only snapshot,
                    // deliberately not a placement lookup: this path runs
                    // per rejected request while holding the shared
                    // receiver lock, exactly when the queue is over cap.
                    let reject = |p: Pending| {
                        let _ = p.reply.send(Err(anyhow::Error::new(Overloaded {
                            model: model_id.clone(),
                            shard,
                            queue_cap: cfg.queue_cap,
                        })));
                    };
                    if let Err(p) = batcher.push(pending) {
                        reject(p);
                    }
                    // Greedily drain what's already waiting in the channel
                    // (requests that arrived while the previous batch
                    // executed) so it coalesces into this batch — up to
                    // `greedy_cap`, so sibling replica workers get their
                    // share of a burst.
                    while batcher.len() < greedy_cap {
                        let Ok(pending) = rx.try_recv() else { break };
                        depth.fetch_sub(1, Ordering::AcqRel);
                        if let Err(p) = batcher.push(pending) {
                            reject(p);
                        }
                    }
                    false
                }
                Err(mpsc::RecvTimeoutError::Timeout) => false,
                Err(mpsc::RecvTimeoutError::Disconnected) => true,
            }
        };
        // Flush phase, lock released: sibling workers can collect while
        // this worker's batches stream into the pipeline window.
        if disconnected {
            // Drain this worker's remaining local work, then exit; the
            // in-flight tickets resolve on the completion thread, which
            // outlives every collect worker.
            while !batcher.is_empty() {
                flush_streaming(&mut batcher);
            }
            return;
        }
        while batcher.should_flush(Instant::now()) {
            flush_streaming(&mut batcher);
        }
    }
}

/// Completion loop, one thread per served model: waits out streamed
/// batches in submission order and scatters each reply. Exits when every
/// collect worker has dropped its job sender and the channel drains.
fn completion_main(done: mpsc::Receiver<FlushJob>) {
    while let Ok(job) = done.recv() {
        let result = job.ticket.wait();
        Batcher::scatter(job.prepared, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_spec_parses_both_forms() {
        let (id, slo) = Slo::parse_spec("mnist=2:50").unwrap();
        assert_eq!(id, "mnist");
        assert_eq!(slo.priority, 2);
        assert_eq!(slo.deadline, Some(Duration::from_millis(50)));
        let (id, slo) = Slo::parse_spec("cifar=7").unwrap();
        assert_eq!(id, "cifar");
        assert_eq!((slo.priority, slo.deadline), (7, None));
        let (_, slo) = Slo::parse_spec("m=1:0").unwrap();
        assert_eq!(slo.deadline, None, "a zero deadline means no deadline");
        for bad in ["mnist", "=1:2", "m=x", "m=1:y"] {
            assert!(Slo::parse_spec(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn shed_policy_is_strictly_lowest_priority_first() {
        let served = [0usize, 1, 2];
        // Below the shed-start saturation nothing sheds.
        for p in served {
            assert!(!should_shed(p, &served, 74, 100));
        }
        // At shed-start the lowest priority sheds, the others hold.
        assert!(should_shed(0, &served, 75, 100));
        assert!(!should_shed(1, &served, 75, 100));
        assert!(!should_shed(2, &served, 75, 100));
        // Midway the middle priority sheds too; the top never does.
        assert!(should_shed(0, &served, 88, 100));
        assert!(should_shed(1, &served, 88, 100));
        assert!(!should_shed(2, &served, 88, 100));
        assert!(!should_shed(2, &served, 100, 100), "top priority never sheds");
        // Shed thresholds are strictly ordered by priority: at every
        // saturation level, if a priority sheds, all lower ones do too.
        for inflight in 0..=100 {
            let flags: Vec<bool> =
                served.iter().map(|&p| should_shed(p, &served, inflight, 100)).collect();
            for w in flags.windows(2) {
                assert!(w[0] || !w[1], "higher priority shed while lower admitted");
            }
        }
    }

    #[test]
    fn uniform_priorities_never_shed() {
        for inflight in [0, 50, 100, 1000] {
            assert!(!should_shed(0, &[0, 0, 0], inflight, 100));
        }
        assert!(!should_shed(0, &[], 100, 100), "no served models, nothing sheds");
        assert!(!should_shed(0, &[0, 1], 100, 0), "zero capacity disables the policy");
    }
}
