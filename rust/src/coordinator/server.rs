//! The coordinator: per-model batcher worker threads in front of the PJRT
//! engine, with end-to-end latency metrics and SLO accounting.

use super::batcher::{Batcher, BatcherConfig, Pending};
use super::NIELSEN_SLO_MICROS;
use crate::metrics::{Histogram, ServingStats};
use crate::runtime::{EngineHandle, ModelInfo};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

/// The result of one request.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// Output row for this request (e.g. class probabilities).
    pub output: Tensor,
    /// Predicted class (argmax) for classifier models.
    pub predicted: usize,
    /// End-to-end latency observed by the coordinator.
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

struct ModelWorker {
    tx: mpsc::Sender<Pending>,
    info: ModelInfo,
}

struct Shared {
    latency_hist: Mutex<Histogram>,
    batch_sizes: Mutex<Vec<usize>>,
    requests: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
    started: Instant,
}

/// Multi-model serving coordinator.
pub struct Coordinator {
    engine: EngineHandle,
    config: CoordinatorConfig,
    workers: BTreeMap<String, ModelWorker>,
    shared: Arc<Shared>,
}

impl Coordinator {
    /// Create a coordinator over an engine.
    pub fn new(engine: EngineHandle, config: CoordinatorConfig) -> Coordinator {
        Coordinator {
            engine,
            config,
            workers: BTreeMap::new(),
            shared: Arc::new(Shared {
                latency_hist: Mutex::new(Histogram::new()),
                batch_sizes: Mutex::new(Vec::new()),
                requests: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                started: Instant::now(),
            }),
        }
    }

    /// Load a model from a directory and start its batcher worker.
    pub fn serve_model(&mut self, dir: impl Into<std::path::PathBuf>) -> crate::Result<ModelInfo> {
        let info = self.engine.load(dir)?;
        let id = info.id.clone();

        // Batch cap: don't exceed the largest AOT batch.
        let mut cfg = self.config.batcher;
        if let Some(&max_aot) = info.batches.iter().max() {
            cfg.max_batch = cfg.max_batch.min(max_aot);
        }

        let (tx, rx) = mpsc::channel::<Pending>();
        let engine = self.engine.clone();
        let shared = self.shared.clone();
        let model_id = id.clone();
        std::thread::Builder::new()
            .name(format!("dlk-batcher-{id}"))
            .spawn(move || batcher_main(rx, cfg, engine, model_id, shared))
            .map_err(|e| anyhow::anyhow!("spawning batcher: {e}"))?;

        self.workers.insert(id, ModelWorker { tx, info: info.clone() });
        Ok(info)
    }

    /// Stop serving a model (drains in-flight work, unloads from engine).
    pub fn retire_model(&mut self, id: &str) -> crate::Result<()> {
        let worker = self
            .workers
            .remove(id)
            .ok_or_else(|| anyhow::anyhow!("model `{id}` is not being served"))?;
        drop(worker); // closes the channel; worker thread drains then exits
        self.engine.unload(id)
    }

    /// Models currently served.
    pub fn served_models(&self) -> Vec<&ModelInfo> {
        self.workers.values().map(|w| &w.info).collect()
    }

    /// Submit one input (no batch dimension) and wait for its result.
    pub fn infer(&self, model_id: &str, input: Tensor) -> crate::Result<RequestResult> {
        self.submit(model_id, input)?.wait()
    }

    /// Submit asynchronously; returns a ticket to wait on.
    pub fn submit(&self, model_id: &str, input: Tensor) -> crate::Result<Ticket> {
        let worker = self
            .workers
            .get(model_id)
            .ok_or_else(|| anyhow::anyhow!("model `{model_id}` is not being served"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let started = Instant::now();
        worker
            .tx
            .send(Pending { input, enqueued: started, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("batcher for `{model_id}` is gone"))?;
        self.shared.requests.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket { reply: reply_rx, started, shared: self.shared.clone() })
    }

    /// Serving statistics snapshot.
    pub fn stats(&self) -> ServingStats {
        let hist = self.shared.latency_hist.lock().unwrap();
        let batch_sizes = self.shared.batch_sizes.lock().unwrap();
        let requests = self.shared.requests.load(Ordering::Relaxed);
        let elapsed = self.shared.started.elapsed().as_secs_f64();
        ServingStats {
            requests,
            batches: self.shared.batches.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            p50_us: hist.quantile(0.5),
            p95_us: hist.quantile(0.95),
            p99_us: hist.quantile(0.99),
            max_us: hist.max(),
            mean_batch_size: if batch_sizes.is_empty() {
                0.0
            } else {
                batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len() as f64
            },
            throughput_rps: if elapsed > 0.0 { hist.count() as f64 / elapsed } else { 0.0 },
            slo_attainment: hist.fraction_under(NIELSEN_SLO_MICROS),
        }
    }

    /// Access to the underlying engine handle.
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }
}

/// A pending request handle.
pub struct Ticket {
    reply: mpsc::Receiver<crate::Result<(Tensor, super::batcher::BatchMeta)>>,
    started: Instant,
    shared: Arc<Shared>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<RequestResult> {
        let result = self
            .reply
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))?;
        let latency = self.started.elapsed();
        match result {
            Ok((output, meta)) => {
                self.shared
                    .latency_hist
                    .lock()
                    .unwrap()
                    .record(latency.as_micros() as u64);
                self.shared.batch_sizes.lock().unwrap().push(meta.batch_size);
                let predicted = output.argmax();
                Ok(RequestResult { output, predicted, latency, batch_size: meta.batch_size })
            }
            Err(e) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }
}

/// Batcher worker loop: poll the channel with the flush deadline as the
/// timeout; execute batches on the engine.
fn batcher_main(
    rx: mpsc::Receiver<Pending>,
    cfg: BatcherConfig,
    engine: EngineHandle,
    model_id: String,
    shared: Arc<Shared>,
) {
    let mut batcher = Batcher::new(cfg);
    loop {
        let now = Instant::now();
        let timeout = batcher
            .next_deadline(now)
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(pending) => {
                let mut reject = |p: Pending| {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = p
                        .reply
                        .send(Err(anyhow::anyhow!("queue full for `{model_id}` (backpressure)")));
                };
                if let Err(p) = batcher.push(pending) {
                    reject(p);
                }
                // Greedily drain everything already waiting in the channel
                // (requests that arrived while the previous batch executed)
                // so they coalesce into this batch.
                while let Ok(pending) = rx.try_recv() {
                    if let Err(p) = batcher.push(pending) {
                        reject(p);
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Drain remaining work, then exit.
                while !batcher.is_empty() {
                    shared.batches.fetch_add(1, Ordering::Relaxed);
                    batcher.flush(|batch| engine.infer(&model_id, batch.clone()));
                }
                return;
            }
        }
        while batcher.should_flush(Instant::now()) {
            shared.batches.fetch_add(1, Ordering::Relaxed);
            batcher.flush(|batch| engine.infer(&model_id, batch.clone()));
        }
    }
}
