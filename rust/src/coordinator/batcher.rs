//! Dynamic batcher: one per served model.
//!
//! Requests accumulate in a queue; a flush happens when either the batch
//! is full (`max_batch`) or the oldest request has waited `max_delay`.
//! Classic serving trade-off: larger batches raise throughput (one PJRT
//! dispatch amortized over more items), the deadline bounds added latency.
//! Experiment E8 sweeps this.
//!
//! The flush is split into two halves so the serving workers can
//! **stream** batches into a shard's pipeline window instead of blocking
//! on completion: [`Batcher::take`] forms a [`PreparedBatch`] (stacked
//! input + the pending repliers), and [`Batcher::scatter`] distributes an
//! execution result back to them. [`Batcher::flush`] composes the two for
//! synchronous callers and tests. Time is injected everywhere
//! ([`Batcher::push_at`], [`Batcher::should_flush`], [`Batcher::take`]
//! all take `now`), so the flush invariants are testable with a synthetic
//! clock — no sleeps.

use crate::runtime::{Overloaded, Routed};
use crate::tensor::{Shape, Tensor};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Batcher configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request is this old.
    pub max_delay: Duration,
    /// Reject requests when the queue holds this many items (backpressure).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// One queued request: a single input (no batch dim) + reply channel.
pub struct Pending {
    pub input: Tensor,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<crate::Result<(Tensor, BatchMeta)>>,
}

/// Batch execution metadata attached to each reply.
#[derive(Clone, Copy, Debug)]
pub struct BatchMeta {
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Time the request waited in the batcher queue (microseconds).
    pub queue_micros: u64,
    /// Engine-pool shard that executed the batch.
    pub shard: usize,
    /// Index of the chosen replica within the model's owner set (0 for an
    /// unreplicated model — the single owner).
    pub replica: usize,
    /// Pipeline-window occupancy on the executing shard when this batch
    /// took its slot (1 = it had the pipeline to itself).
    pub window: usize,
    /// Stage-phase time for the batch on the shard (microseconds).
    pub stage_micros: u64,
    /// Execute-phase time for the batch on the shard (microseconds).
    pub exec_micros: u64,
}

/// A formed batch en route to execution: the stacked `[n, ...]` input plus
/// the repliers awaiting its rows. Produced by [`Batcher::take`], resolved
/// by [`Batcher::scatter`] — in between it can sit in a shard's pipeline
/// window while the batcher keeps collecting.
pub struct PreparedBatch {
    input: Tensor,
    batch: Vec<Pending>,
    /// When the batch was formed (each reply's `queue_micros` measures
    /// enqueue → this point).
    taken: Instant,
}

impl PreparedBatch {
    /// The stacked `[n, ...per-item dims]` input tensor.
    pub fn input(&self) -> &Tensor {
        &self.input
    }

    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.batch.len()
    }

    /// Whether the batch is empty (never true for a `take`-produced batch).
    pub fn is_empty(&self) -> bool {
        self.batch.is_empty()
    }
}

/// The batching core: owns the queue, decides when to flush. Execution is
/// delegated to the caller so the same logic is testable without a PJRT
/// engine.
///
/// The flush deadline counts from when the oldest request was *pushed into
/// this queue*, not from client submit time: requests that waited in the
/// channel while the previous batch executed would otherwise arrive
/// "already expired" and flush as singletons — the anti-synchronized
/// closed-loop fixed point documented in EXPERIMENTS.md §Perf (L3).
pub struct Batcher {
    config: BatcherConfig,
    queue: Vec<Pending>,
    /// When the oldest currently-queued request entered the queue.
    oldest_pushed: Option<Instant>,
}

impl Batcher {
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher { config, queue: Vec::new(), oldest_pushed: None }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue a request. Errors (backpressure) if the queue is full.
    pub fn push(&mut self, pending: Pending) -> Result<(), Pending> {
        self.push_at(pending, Instant::now())
    }

    /// [`Batcher::push`] with an injected clock: `now` becomes the
    /// deadline anchor when this push makes the queue non-empty. The
    /// queue-cap check is exact — the push that would make the queue hold
    /// `queue_cap + 1` requests is the first one rejected.
    pub fn push_at(&mut self, pending: Pending, now: Instant) -> Result<(), Pending> {
        if self.queue.len() >= self.config.queue_cap {
            return Err(pending);
        }
        if self.queue.is_empty() {
            self.oldest_pushed = Some(now);
        }
        self.queue.push(pending);
        Ok(())
    }

    /// Should the queue be flushed now?
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.config.max_batch {
            return true;
        }
        match self.oldest_pushed {
            Some(t) => now.duration_since(t) >= self.config.max_delay,
            None => false,
        }
    }

    /// Time until the deadline flush of the oldest request (for the worker's
    /// poll timeout), or None if the queue is empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest_pushed.map(|t| {
            self.config
                .max_delay
                .saturating_sub(now.duration_since(t))
        })
    }

    /// Form a batch: drain up to `max_batch` requests and stack their
    /// inputs into one `[n, ...]` tensor. Returns `None` when the queue is
    /// empty or the drained requests mixed per-item shapes (those all get
    /// an error reply here — a malformed batch never reaches execution).
    /// `now` re-anchors the deadline for whatever stays queued.
    pub fn take(&mut self, now: Instant) -> Option<PreparedBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.queue.len().min(self.config.max_batch);
        let batch: Vec<Pending> = self.queue.drain(..take).collect();
        self.oldest_pushed = if self.queue.is_empty() { None } else { Some(now) };
        let n = batch.len();

        // Stack inputs: all must share the per-item shape.
        let item_shape = batch[0].input.shape().clone();
        if batch[1..].iter().any(|p| p.input.shape() != &item_shape) {
            for p in batch {
                let _ = p
                    .reply
                    .send(Err(anyhow::anyhow!("mixed input shapes in one model queue")));
            }
            return None;
        }
        let mut data = Vec::with_capacity(n * item_shape.numel());
        for p in &batch {
            data.extend_from_slice(p.input.data());
        }
        let mut dims = vec![n];
        dims.extend_from_slice(item_shape.dims());
        let input = Tensor::new(Shape::new(&dims), data).expect("stack shapes consistent");
        Some(PreparedBatch { input, batch, taken: now })
    }

    /// Resolve a formed batch: scatter output rows (with per-request
    /// [`BatchMeta`]) or the failure back to every reply channel. Typed
    /// `Overloaded` rejections are re-wrapped per requester so each caller
    /// can downcast and apply backoff. An associated function — by the
    /// time results arrive the batcher may already be collecting the next
    /// batch, possibly on another thread.
    pub fn scatter(prepared: PreparedBatch, result: crate::Result<(Tensor, Routed)>) {
        let n = prepared.batch.len();
        match result {
            Ok((out, routed)) => {
                // Scatter rows back. Output is [n, ...per-item dims].
                let row = out.numel() / n;
                let out_dims: Vec<usize> = out.shape().dims()[1..].to_vec();
                for (i, p) in prepared.batch.into_iter().enumerate() {
                    let slice = out.data()[i * row..(i + 1) * row].to_vec();
                    let t = Tensor::new(Shape::new(&out_dims), slice).expect("row shape");
                    let meta = BatchMeta {
                        batch_size: n,
                        queue_micros: prepared.taken.duration_since(p.enqueued).as_micros()
                            as u64,
                        shard: routed.shard,
                        replica: routed.replica,
                        window: routed.window,
                        stage_micros: routed.stage_micros,
                        exec_micros: routed.exec_micros,
                    };
                    let _ = p.reply.send(Ok((t, meta)));
                }
            }
            Err(e) => {
                // Every requester in the batch gets the failure.
                let overloaded = e.downcast_ref::<Overloaded>().cloned();
                let msg = e.to_string();
                for p in prepared.batch {
                    let err = match &overloaded {
                        Some(o) => anyhow::Error::new(o.clone()),
                        None => anyhow::anyhow!("batch execution failed: {msg}"),
                    };
                    let _ = p.reply.send(Err(err));
                }
            }
        }
    }

    /// Synchronous flush: [`Batcher::take`] one batch, run `exec`, and
    /// [`Batcher::scatter`] the result. The streaming workers use the two
    /// halves directly so execution overlaps collection.
    pub fn flush(&mut self, exec: impl FnOnce(&Tensor) -> crate::Result<(Tensor, Routed)>) {
        if let Some(prepared) = self.take(Instant::now()) {
            let result = exec(prepared.input());
            Batcher::scatter(prepared, result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::XorShiftRng;

    fn pending(v: f32) -> (Pending, mpsc::Receiver<crate::Result<(Tensor, BatchMeta)>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                input: Tensor::filled(&[2][..], v),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        assert!(!b.should_flush(Instant::now()));
        b.push(p2).map_err(|_| ()).unwrap();
        assert!(b.should_flush(Instant::now()));

        // exec: identity + 10, "executed on shard 5, replica 1 of 2".
        b.flush(|x| {
            assert_eq!(x.shape().dims(), &[2, 2]);
            let mut out = x.clone();
            for v in out.data_mut() {
                *v += 10.0;
            }
            Ok((out, Routed::at(5, 1, 2)))
        });
        let (t1, m1) = r1.recv().unwrap().unwrap();
        let (t2, m2) = r2.recv().unwrap().unwrap();
        assert_eq!(t1.data(), &[11.0, 11.0]);
        assert_eq!(t2.data(), &[12.0, 12.0]);
        assert_eq!(m1.batch_size, 2);
        assert_eq!(m1.shard, 5);
        assert_eq!(m1.replica, 1);
        assert_eq!(m2.shard, 5);
        assert_eq!(m2.replica, 1);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        // Injected clock: one request, pushed at t0, must deadline-flush at
        // exactly t0 + max_delay — no sooner, no sleeps.
        let cfg = BatcherConfig {
            max_batch: 100,
            max_delay: Duration::from_millis(5),
            ..Default::default()
        };
        let mut b = Batcher::new(cfg);
        let t0 = Instant::now();
        let (p, _r) = pending(1.0);
        b.push_at(p, t0).map_err(|_| ()).unwrap();
        assert!(!b.should_flush(t0));
        assert!(!b.should_flush(t0 + Duration::from_micros(4_999)));
        assert!(b.should_flush(t0 + Duration::from_millis(5)));
        assert!(b.should_flush(t0 + Duration::from_millis(50)));
        assert_eq!(b.next_deadline(t0), Some(Duration::from_millis(5)));
        assert_eq!(
            b.next_deadline(t0 + Duration::from_millis(3)),
            Some(Duration::from_millis(2))
        );
    }

    #[test]
    fn deadline_anchors_to_oldest_queued_not_newest() {
        let cfg = BatcherConfig {
            max_batch: 100,
            max_delay: Duration::from_millis(5),
            ..Default::default()
        };
        let mut b = Batcher::new(cfg);
        let t0 = Instant::now();
        let (p1, _r1) = pending(1.0);
        let (p2, _r2) = pending(2.0);
        b.push_at(p1, t0).map_err(|_| ()).unwrap();
        // A later push must NOT extend the oldest request's deadline.
        b.push_at(p2, t0 + Duration::from_millis(4)).map_err(|_| ()).unwrap();
        assert!(b.should_flush(t0 + Duration::from_millis(5)));
        // After a partial take, the remainder re-anchors to the take time.
        let cfg2 = BatcherConfig { max_batch: 1, ..cfg };
        let mut b2 = Batcher::new(cfg2);
        let (q1, _s1) = pending(1.0);
        let (q2, _s2) = pending(2.0);
        b2.push_at(q1, t0).map_err(|_| ()).unwrap();
        b2.push_at(q2, t0).map_err(|_| ()).unwrap();
        let taken = b2.take(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(taken.len(), 1);
        assert_eq!(b2.len(), 1);
        // The leftover's deadline counts from the take, not its push.
        assert!(!b2.should_flush(t0 + Duration::from_millis(12)));
        assert!(b2.should_flush(t0 + Duration::from_millis(15)));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let cfg = BatcherConfig { queue_cap: 1, ..Default::default() };
        let mut b = Batcher::new(cfg);
        let (p1, _r1) = pending(1.0);
        let (p2, _r2) = pending(2.0);
        assert!(b.push(p1).is_ok());
        assert!(b.push(p2).is_err());
    }

    #[test]
    fn queue_cap_boundary_is_exact() {
        // Off-by-one pin: cap pushes are admitted, push cap+1 is rejected,
        // and draining one slot re-admits exactly one.
        for cap in [1usize, 2, 7, 64] {
            let cfg = BatcherConfig { queue_cap: cap, max_batch: 1, ..Default::default() };
            let mut b = Batcher::new(cfg);
            for i in 0..cap {
                let (p, _r) = pending(i as f32);
                assert!(b.push(p).is_ok(), "push {i} of cap {cap} must be admitted");
            }
            assert_eq!(b.len(), cap);
            let (p_over, _r_over) = pending(-1.0);
            assert!(b.push(p_over).is_err(), "push cap+1 must be rejected at cap {cap}");
            assert_eq!(b.len(), cap, "a rejected push must not grow the queue");
            // One take frees exactly one slot (max_batch = 1).
            let prepared = b.take(Instant::now()).unwrap();
            assert_eq!(prepared.len(), 1);
            let (p_next, _r_next) = pending(-2.0);
            assert!(b.push(p_next).is_ok(), "one drained slot re-admits one push");
            let (p_again, _r_again) = pending(-3.0);
            assert!(b.push(p_again).is_err(), "and only one");
        }
    }

    #[test]
    fn flush_invariants_hold_under_random_schedules() {
        // Property sweep with a synthetic clock: for random configs and
        // random push/advance schedules,
        //   (1) should_flush ⟺ (len >= max_batch) ∨ (oldest age >= max_delay)
        //   (2) a take never exceeds max_batch and drains oldest-first
        //   (3) admitted + rejected == offered, admitted <= queue_cap.
        for seed in 0..20u64 {
            let mut rng = XorShiftRng::new(1000 + seed);
            let max_batch = rng.range_usize(1, 9);
            let queue_cap = rng.range_usize(max_batch, max_batch + 16);
            let delay_us = rng.range_usize(100, 5000) as u64;
            let cfg = BatcherConfig {
                max_batch,
                queue_cap,
                max_delay: Duration::from_micros(delay_us),
            };
            let mut b = Batcher::new(cfg);
            let t0 = Instant::now();
            let mut now = t0;
            let mut oldest: Option<Instant> = None;
            let mut queued = 0usize;
            for step in 0..200 {
                if rng.bernoulli(0.6) {
                    let (p, _r) = pending(step as f32);
                    std::mem::forget(_r); // keep reply channels open
                    let admitted = b.push_at(p, now).is_ok();
                    assert_eq!(admitted, queued < queue_cap, "seed {seed} step {step}");
                    if admitted {
                        if queued == 0 {
                            oldest = Some(now);
                        }
                        queued += 1;
                    }
                } else {
                    now += Duration::from_micros(rng.range_usize(0, 2 * delay_us as usize) as u64);
                }
                let expect = queued >= max_batch
                    || (queued > 0
                        && now.duration_since(oldest.unwrap()).as_micros() as u64 >= delay_us);
                assert_eq!(b.should_flush(now), expect, "seed {seed} step {step}");
                if b.should_flush(now) && rng.bernoulli(0.7) {
                    let before = queued;
                    let prepared = b.take(now).expect("flushable queue yields a batch");
                    assert!(prepared.len() <= max_batch, "seed {seed} step {step}");
                    assert_eq!(prepared.len(), before.min(max_batch));
                    queued -= prepared.len();
                    oldest = if queued == 0 { None } else { Some(now) };
                    // Oldest-first: the stacked rows are the earliest pushes.
                    let first = prepared.input().data()[0];
                    for later in b.queue.iter() {
                        assert!(later.input.data()[0] > first, "seed {seed} step {step}");
                    }
                }
            }
        }
    }

    #[test]
    fn exec_error_propagates_to_all() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        b.push(p2).map_err(|_| ()).unwrap();
        b.flush(|_| Err(anyhow::anyhow!("engine on fire")));
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }

    #[test]
    fn overloaded_stays_typed_for_every_requester() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        b.push(p2).map_err(|_| ()).unwrap();
        b.flush(|_| {
            Err(anyhow::Error::new(Overloaded { model: "m".into(), shard: 1, queue_cap: 4 }))
        });
        for r in [r1, r2] {
            let e = r.recv().unwrap().unwrap_err();
            let o = e.downcast_ref::<Overloaded>().expect("typed Overloaded");
            assert_eq!(o.shard, 1);
        }
    }

    #[test]
    fn partial_flush_takes_max_batch() {
        let cfg = BatcherConfig { max_batch: 2, queue_cap: 10, ..Default::default() };
        let mut b = Batcher::new(cfg);
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (p, r) = pending(i as f32);
            b.push(p).map_err(|_| ()).unwrap();
            receivers.push(r);
        }
        b.flush(|x| Ok((x.clone(), Routed::at(0, 0, 1))));
        assert_eq!(b.len(), 3);
        assert!(receivers[0].try_recv().unwrap().is_ok());
        assert!(receivers[1].try_recv().unwrap().is_ok());
        assert!(receivers[2].try_recv().is_err()); // still queued
    }

    #[test]
    fn scatter_carries_pipeline_trace_into_meta() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (p, r) = pending(1.0);
        b.push(p).map_err(|_| ()).unwrap();
        let prepared = b.take(Instant::now()).unwrap();
        let out = prepared.input().clone();
        let routed = Routed {
            shard: 2,
            replica: 0,
            replicas: 1,
            window: 3,
            stage_micros: 17,
            exec_micros: 410,
        };
        Batcher::scatter(prepared, Ok((out, routed)));
        let (_, meta) = r.recv().unwrap().unwrap();
        assert_eq!(meta.window, 3);
        assert_eq!(meta.stage_micros, 17);
        assert_eq!(meta.exec_micros, 410);
        assert_eq!(meta.shard, 2);
    }

    #[test]
    fn mixed_shapes_rejected() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (tx1, r1) = mpsc::channel();
        let (tx2, r2) = mpsc::channel();
        b.push(Pending {
            input: Tensor::zeros(&[2][..]),
            enqueued: Instant::now(),
            reply: tx1,
        })
        .map_err(|_| ())
        .unwrap();
        b.push(Pending {
            input: Tensor::zeros(&[3][..]),
            enqueued: Instant::now(),
            reply: tx2,
        })
        .map_err(|_| ())
        .unwrap();
        // A mixed-shape drain errors every requester and never yields a
        // batch for execution.
        assert!(b.take(Instant::now()).is_none());
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }
}
