//! Dynamic batcher: one per served model.
//!
//! Requests accumulate in a queue; a flush happens when either the batch
//! is full (`max_batch`) or the oldest request has waited `max_delay`.
//! Classic serving trade-off: larger batches raise throughput (one PJRT
//! dispatch amortized over more items), the deadline bounds added latency.
//! Experiment E8 sweeps this.

use crate::runtime::{Overloaded, Routed};
use crate::tensor::{Shape, Tensor};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Batcher configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request is this old.
    pub max_delay: Duration,
    /// Reject requests when the queue holds this many items (backpressure).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// One queued request: a single input (no batch dim) + reply channel.
pub struct Pending {
    pub input: Tensor,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<crate::Result<(Tensor, BatchMeta)>>,
}

/// Batch execution metadata attached to each reply.
#[derive(Clone, Copy, Debug)]
pub struct BatchMeta {
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Time the request waited in the batcher queue (microseconds).
    pub queue_micros: u64,
    /// Engine-pool shard that executed the batch.
    pub shard: usize,
    /// Index of the chosen replica within the model's owner set (0 for an
    /// unreplicated model — the single owner).
    pub replica: usize,
}

/// The batching core: owns the queue, decides when to flush. Execution is
/// delegated to the caller-provided closure so the same logic is testable
/// without a PJRT engine.
///
/// The flush deadline counts from when the oldest request was *pushed into
/// this queue*, not from client submit time: requests that waited in the
/// channel while the previous batch executed would otherwise arrive
/// "already expired" and flush as singletons — the anti-synchronized
/// closed-loop fixed point documented in EXPERIMENTS.md §Perf (L3).
pub struct Batcher {
    config: BatcherConfig,
    queue: Vec<Pending>,
    /// When the oldest currently-queued request entered the queue.
    oldest_pushed: Option<Instant>,
}

impl Batcher {
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher { config, queue: Vec::new(), oldest_pushed: None }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue a request. Errors (backpressure) if the queue is full.
    pub fn push(&mut self, pending: Pending) -> Result<(), Pending> {
        if self.queue.len() >= self.config.queue_cap {
            return Err(pending);
        }
        if self.queue.is_empty() {
            self.oldest_pushed = Some(Instant::now());
        }
        self.queue.push(pending);
        Ok(())
    }

    /// Should the queue be flushed now?
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.config.max_batch {
            return true;
        }
        match self.oldest_pushed {
            Some(t) => now.duration_since(t) >= self.config.max_delay,
            None => false,
        }
    }

    /// Time until the deadline flush of the oldest request (for the worker's
    /// poll timeout), or None if the queue is empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest_pushed.map(|t| {
            self.config
                .max_delay
                .saturating_sub(now.duration_since(t))
        })
    }

    /// Take up to `max_batch` requests, stack their inputs into one batch
    /// tensor, run `exec`, and scatter results (or the error) back to every
    /// reply channel. `exec` returns the output batch plus the routing
    /// decision — which shard/replica executed it (surfaced to clients via
    /// [`BatchMeta`]).
    pub fn flush(&mut self, exec: impl FnOnce(&Tensor) -> crate::Result<(Tensor, Routed)>) {
        if self.queue.is_empty() {
            return;
        }
        let take = self.queue.len().min(self.config.max_batch);
        let batch: Vec<Pending> = self.queue.drain(..take).collect();
        self.oldest_pushed = if self.queue.is_empty() { None } else { Some(Instant::now()) };
        let n = batch.len();
        let now = Instant::now();

        // Stack inputs: all must share the per-item shape.
        let item_shape = batch[0].input.shape().clone();
        let mut ok_shapes = true;
        for p in &batch[1..] {
            if p.input.shape() != &item_shape {
                ok_shapes = false;
            }
        }
        if !ok_shapes {
            for p in batch {
                let _ = p
                    .reply
                    .send(Err(anyhow::anyhow!("mixed input shapes in one model queue")));
            }
            return;
        }
        let mut data = Vec::with_capacity(n * item_shape.numel());
        for p in &batch {
            data.extend_from_slice(p.input.data());
        }
        let mut dims = vec![n];
        dims.extend_from_slice(item_shape.dims());
        let stacked = Tensor::new(Shape::new(&dims), data).expect("stack shapes consistent");

        match exec(&stacked) {
            Ok((out, routed)) => {
                // Scatter rows back. Output is [n, ...per-item dims].
                let row = out.numel() / n;
                let out_dims: Vec<usize> = out.shape().dims()[1..].to_vec();
                for (i, p) in batch.into_iter().enumerate() {
                    let slice = out.data()[i * row..(i + 1) * row].to_vec();
                    let t = Tensor::new(Shape::new(&out_dims), slice).expect("row shape");
                    let meta = BatchMeta {
                        batch_size: n,
                        queue_micros: now.duration_since(p.enqueued).as_micros() as u64,
                        shard: routed.shard,
                        replica: routed.replica,
                    };
                    let _ = p.reply.send(Ok((t, meta)));
                }
            }
            Err(e) => {
                // Every requester in the batch gets the failure. Typed
                // `Overloaded` rejections are re-wrapped per requester so
                // each caller can downcast and apply backoff.
                let overloaded = e.downcast_ref::<Overloaded>().cloned();
                let msg = e.to_string();
                for p in batch {
                    let err = match &overloaded {
                        Some(o) => anyhow::Error::new(o.clone()),
                        None => anyhow::anyhow!("batch execution failed: {msg}"),
                    };
                    let _ = p.reply.send(Err(err));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(v: f32) -> (Pending, mpsc::Receiver<crate::Result<(Tensor, BatchMeta)>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                input: Tensor::filled(&[2][..], v),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        assert!(!b.should_flush(Instant::now()));
        b.push(p2).map_err(|_| ()).unwrap();
        assert!(b.should_flush(Instant::now()));

        // exec: identity + 10, "executed on shard 5, replica 1 of 2".
        b.flush(|x| {
            assert_eq!(x.shape().dims(), &[2, 2]);
            let mut out = x.clone();
            for v in out.data_mut() {
                *v += 10.0;
            }
            Ok((out, Routed { shard: 5, replica: 1, replicas: 2 }))
        });
        let (t1, m1) = r1.recv().unwrap().unwrap();
        let (t2, m2) = r2.recv().unwrap().unwrap();
        assert_eq!(t1.data(), &[11.0, 11.0]);
        assert_eq!(t2.data(), &[12.0, 12.0]);
        assert_eq!(m1.batch_size, 2);
        assert_eq!(m1.shard, 5);
        assert_eq!(m1.replica, 1);
        assert_eq!(m2.shard, 5);
        assert_eq!(m2.replica, 1);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let cfg = BatcherConfig {
            max_batch: 100,
            max_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let mut b = Batcher::new(cfg);
        let (p, _r) = pending(1.0);
        b.push(p).map_err(|_| ()).unwrap();
        assert!(!b.should_flush(Instant::now()));
        std::thread::sleep(Duration::from_millis(2));
        assert!(b.should_flush(Instant::now()));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let cfg = BatcherConfig { queue_cap: 1, ..Default::default() };
        let mut b = Batcher::new(cfg);
        let (p1, _r1) = pending(1.0);
        let (p2, _r2) = pending(2.0);
        assert!(b.push(p1).is_ok());
        assert!(b.push(p2).is_err());
    }

    #[test]
    fn exec_error_propagates_to_all() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        b.push(p2).map_err(|_| ()).unwrap();
        b.flush(|_| Err(anyhow::anyhow!("engine on fire")));
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }

    #[test]
    fn overloaded_stays_typed_for_every_requester() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (p1, r1) = pending(1.0);
        let (p2, r2) = pending(2.0);
        b.push(p1).map_err(|_| ()).unwrap();
        b.push(p2).map_err(|_| ()).unwrap();
        b.flush(|_| {
            Err(anyhow::Error::new(Overloaded { model: "m".into(), shard: 1, queue_cap: 4 }))
        });
        for r in [r1, r2] {
            let e = r.recv().unwrap().unwrap_err();
            let o = e.downcast_ref::<Overloaded>().expect("typed Overloaded");
            assert_eq!(o.shard, 1);
        }
    }

    #[test]
    fn partial_flush_takes_max_batch() {
        let cfg = BatcherConfig { max_batch: 2, queue_cap: 10, ..Default::default() };
        let mut b = Batcher::new(cfg);
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (p, r) = pending(i as f32);
            b.push(p).map_err(|_| ()).unwrap();
            receivers.push(r);
        }
        b.flush(|x| Ok((x.clone(), Routed { shard: 0, replica: 0, replicas: 1 })));
        assert_eq!(b.len(), 3);
        assert!(receivers[0].try_recv().unwrap().is_ok());
        assert!(receivers[1].try_recv().unwrap().is_ok());
        assert!(receivers[2].try_recv().is_err()); // still queued
    }

    #[test]
    fn mixed_shapes_rejected() {
        let mut b = Batcher::new(BatcherConfig::default());
        let (tx1, r1) = mpsc::channel();
        let (tx2, r2) = mpsc::channel();
        b.push(Pending {
            input: Tensor::zeros(&[2][..]),
            enqueued: Instant::now(),
            reply: tx1,
        })
        .map_err(|_| ())
        .unwrap();
        b.push(Pending {
            input: Tensor::zeros(&[3][..]),
            enqueued: Instant::now(),
            reply: tx2,
        })
        .map_err(|_| ())
        .unwrap();
        b.flush(|x| Ok((x.clone(), Routed { shard: 0, replica: 0, replicas: 1 })));
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }
}
