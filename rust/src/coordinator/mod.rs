//! Serving coordinator: request routing, dynamic batching, SLO tracking.
//!
//! The paper's on-device serving story — "intelligently (and very rapid …)
//! switch between several Deep Learning Models", answer within Nielsen's
//! 100 ms "feels instantaneous" bar (§1.1) — realized as a multi-threaded
//! coordinator in front of the sharded engine pool:
//!
//! ```text
//! client threads ──submit──► per-replica Batcher ──batches──► PoolHandle
//!                 (admission   workers (shared        (model → owner set,
//!                  control)    queue, size/deadline)   p2c replica pick)
//!                                                            │
//!                                                  engine shard threads
//! ```
//!
//! Admission control happens at `submit`: a model whose queue is at
//! `queue_cap` rejects with the typed
//! [`Overloaded`](crate::runtime::Overloaded) error instead of queueing
//! without bound. Downstream, formed batches **stream** into the routed
//! shard's pipeline window (`PoolHandle::infer_async`) and resolve on a
//! per-model completion thread, so batch collection overlaps execution;
//! a full window also surfaces as `Overloaded`.
//!
//! Admission is additionally **SLO-aware** when per-model [`Slo`]s are
//! configured: near pool saturation, lower-priority traffic is shed
//! (typed [`Shed`](crate::runtime::Shed), strictly
//! lowest-priority-first — see [`should_shed`]), and a model with a
//! deadline whose predicted latency (plan-cost forward estimate plus
//! observed queue delay) would bust it is answered by a cheaper
//! compatible ladder model instead, with the substitution recorded in
//! [`RequestResult::degraded_from`].

mod batcher;
mod server;

pub use batcher::{BatchMeta, Batcher, BatcherConfig, Pending, PreparedBatch};
pub use server::{should_shed, Coordinator, CoordinatorConfig, RequestResult, Slo, Ticket};

/// Nielsen's "feels instantaneous" bar the paper cites (§1.1).
pub const NIELSEN_SLO_MICROS: u64 = 100_000;
