//! Energy model (paper Figures 10–12): the train-vs-inference asymmetry.
//!
//! The paper illustrates that training a deep network takes "piles of
//! wood" of energy (weeks on a Titan X) while running one inference takes
//! less than lighting a match. This module computes those joules from the
//! FLOP counts and device tiers, and expresses them in the paper's own
//! units (matches and kg of firewood).

use crate::device::DeviceTier;

/// Energy of one burning match, ~1 kJ (the paper's inference-scale unit).
pub const MATCH_JOULES: f64 = 1_000.0;
/// Energy content of dry firewood, ~16 MJ/kg (the training-scale unit).
pub const FIREWOOD_JOULES_PER_KG: f64 = 16_000_000.0;

/// Energy estimate for a workload on a tier.
#[derive(Clone, Copy, Debug)]
pub struct EnergyEstimate {
    pub joules: f64,
    pub seconds: f64,
    pub watts: f64,
}

impl EnergyEstimate {
    /// Express in burning matches.
    pub fn matches(&self) -> f64 {
        self.joules / MATCH_JOULES
    }

    /// Express in kg of firewood.
    pub fn firewood_kg(&self) -> f64 {
        self.joules / FIREWOOD_JOULES_PER_KG
    }
}

/// Energy of running `flops` on a tier at its sustained efficiency.
pub fn compute_energy(tier: &DeviceTier, flops: f64) -> EnergyEstimate {
    let seconds = flops / (tier.gflops * 1e9 * tier.efficiency);
    EnergyEstimate { joules: seconds * tier.watts, seconds, watts: tier.watts }
}

/// Energy of a full training run: `steps` optimizer steps at `batch`
/// items, where backward ≈ 2x forward (so 3x forward per item).
pub fn training_energy(
    tier: &DeviceTier,
    forward_flops_per_item: f64,
    batch: usize,
    steps: u64,
) -> EnergyEstimate {
    let total = forward_flops_per_item * 3.0 * batch as f64 * steps as f64;
    compute_energy(tier, total)
}

/// Inference energy for one item.
pub fn inference_energy(tier: &DeviceTier, forward_flops: f64) -> EnergyEstimate {
    compute_energy(tier, forward_flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::tier;

    #[test]
    fn asymmetry_matches_figures_10_12() {
        // NIN-CIFAR10: ~445 MFLOPs forward. Train: 120k steps @ batch 128
        // on a Titan X (typical CIFAR schedule).
        let titan = tier("nvidia-titanx").unwrap();
        let phone = tier("powervr-gt7600").unwrap();
        let train = training_energy(&titan, 445e6, 128, 120_000);
        let infer = inference_energy(&phone, 445e6);

        // Training: >= several kg of firewood.
        assert!(train.firewood_kg() > 0.05, "training {} kg", train.firewood_kg());
        // Inference: a small fraction of one match.
        assert!(infer.matches() < 0.1, "inference {} matches", infer.matches());
        // The asymmetry the figures illustrate: >=10^6.
        assert!(train.joules / infer.joules > 1e6, "ratio {}", train.joules / infer.joules);
    }

    #[test]
    fn energy_scales_linearly_with_flops() {
        let t = tier("powervr-gt7600").unwrap();
        let a = compute_energy(&t, 1e9);
        let b = compute_energy(&t, 2e9);
        assert!((b.joules / a.joules - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unit_conversions() {
        let e = EnergyEstimate { joules: 16_000_000.0, seconds: 1.0, watts: 1.0 };
        assert!((e.firewood_kg() - 1.0).abs() < 1e-12);
        assert!((e.matches() - 16_000.0).abs() < 1e-9);
    }
}
