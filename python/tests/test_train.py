"""Trainer tests: loss decreases, accuracy beats chance quickly."""

import numpy as np

from compile.train import adam_init, adam_update, cross_entropy, train_lenet

import jax.numpy as jnp


def test_cross_entropy_known_value():
    logits = jnp.asarray([[10.0, 0.0, 0.0]])
    labels = jnp.asarray([0])
    assert float(cross_entropy(logits, labels)) < 1e-3
    wrong = jnp.asarray([2])
    assert float(cross_entropy(logits, wrong)) > 5.0


def test_adam_moves_toward_minimum():
    # Minimize (w - 3)^2 with Adam.
    params = {"w": jnp.asarray(0.0)}
    state = adam_init(params)
    for _ in range(400):
        grads = {"w": 2 * (params["w"] - 3.0)}
        params, state = adam_update(params, grads, state, lr=0.05)
    assert abs(float(params["w"]) - 3.0) < 0.05


def test_short_training_learns():
    """40 steps must already beat chance (10%) comfortably."""
    params, acc, losses = train_lenet(steps=40, batch=32, verbose=False)
    assert losses[-1] < losses[0], "loss did not decrease"
    assert acc > 0.3, f"accuracy {acc} not above chance"
    # Parameters are finite.
    for k, v in params.items():
        assert np.isfinite(np.asarray(v)).all(), k
