"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/strides/paddings/dtypes — the python half of the
correctness contract (the rust half checks the CPU backend and the PJRT
runtime against each other).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    avg_pool2d_pallas,
    conv1d_pallas,
    conv2d_pallas,
    fake_quant_matmul_pallas,
    global_avg_pool_pallas,
    matmul_pallas,
    max_pool2d_pallas,
    quantize_symmetric,
    relu_pallas,
    softmax_pallas,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---- matmul ---------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(
        matmul_pallas(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_matmul_tile_aligned_and_tiny():
    rng = np.random.default_rng(0)
    for m, k, n in [(128, 512, 128), (256, 1024, 256), (1, 1, 1), (1, 7, 1)]:
        x, y = rand(rng, m, k), rand(rng, k, n)
        np.testing.assert_allclose(
            matmul_pallas(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-3
        )


def test_matmul_rejects_bad_inner_dim():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        matmul_pallas(rand(rng, 4, 5), rand(rng, 6, 3))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31),
)
def test_matmul_dtypes(dtype, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(33, 65)), dtype)
    y = jnp.asarray(rng.normal(size=(65, 17)), dtype)
    got = matmul_pallas(x, y)
    expect = ref.matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, expect, rtol=tol, atol=tol)


# ---- conv2d ---------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 3),
    c=st.integers(1, 5),
    oc=st.integers(1, 6),
    hw=st.integers(5, 20),
    k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31),
)
def test_conv2d_matches_ref(n, c, oc, hw, k, stride, pad, seed):
    hypothesis.assume(hw + 2 * pad >= k)
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, hw, hw)
    w = rand(rng, oc, c, k, k)
    b = rand(rng, oc)
    np.testing.assert_allclose(
        conv2d_pallas(x, w, b, stride=stride, pad=pad),
        ref.conv2d_ref(x, w, b, stride=stride, pad=pad),
        rtol=1e-3,
        atol=1e-3,
    )


def test_conv2d_nin_shapes():
    """The exact conv shapes of the paper's NIN net."""
    rng = np.random.default_rng(7)
    x = rand(rng, 1, 3, 32, 32)
    w = rand(rng, 192, 3, 5, 5)
    b = rand(rng, 192)
    y = conv2d_pallas(x, w, b, stride=1, pad=2)
    assert y.shape == (1, 192, 32, 32)
    np.testing.assert_allclose(
        y, ref.conv2d_ref(x, w, b, stride=1, pad=2), rtol=1e-3, atol=1e-3
    )


def test_conv2d_shape_errors():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        conv2d_pallas(rand(rng, 1, 3, 8, 8), rand(rng, 4, 2, 3, 3), None)
    with pytest.raises(ValueError):
        conv2d_pallas(rand(rng, 1, 3, 8, 8), rand(rng, 4, 3, 3, 5), None)


# ---- conv1d ---------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 3),
    c=st.integers(1, 5),
    oc=st.integers(1, 6),
    l=st.integers(6, 40),
    k=st.sampled_from([1, 3, 7]),
    stride=st.integers(1, 3),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31),
)
def test_conv1d_matches_ref(n, c, oc, l, k, stride, pad, seed):
    hypothesis.assume(l + 2 * pad >= k)
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, l)
    w = rand(rng, oc, c, k)
    b = rand(rng, oc)
    np.testing.assert_allclose(
        conv1d_pallas(x, w, b, stride=stride, pad=pad),
        ref.conv1d_ref(x, w, b, stride=stride, pad=pad),
        rtol=1e-3,
        atol=1e-3,
    )


# ---- pooling --------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 3),
    c=st.integers(1, 4),
    hw=st.integers(4, 24),
    k=st.integers(2, 4),
    stride=st.integers(1, 3),
    pad=st.integers(0, 1),
    seed=st.integers(0, 2**31),
)
def test_max_pool2d_matches_ref(n, c, hw, k, stride, pad, seed):
    hypothesis.assume(pad < k)
    hypothesis.assume(hw + 2 * pad >= k)
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, hw, hw)
    np.testing.assert_allclose(
        max_pool2d_pallas(x, k=k, stride=stride, pad=pad),
        ref.max_pool2d_ref(x, k=k, stride=stride, pad=pad),
        rtol=1e-5,
        atol=1e-5,
    )


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 2),
    c=st.integers(1, 4),
    hw=st.integers(4, 24),
    k=st.integers(2, 4),
    stride=st.integers(1, 3),
    pad=st.integers(0, 1),
    seed=st.integers(0, 2**31),
)
def test_avg_pool2d_matches_ref(n, c, hw, k, stride, pad, seed):
    hypothesis.assume(pad < k)
    hypothesis.assume(hw + 2 * pad >= k)
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, hw, hw)
    np.testing.assert_allclose(
        avg_pool2d_pallas(x, k=k, stride=stride, pad=pad),
        ref.avg_pool2d_ref(x, k=k, stride=stride, pad=pad),
        rtol=1e-4,
        atol=1e-5,
    )


def test_pool_nin_cases():
    """NIN's exact pools: 3x3 stride-2 ceil mode on 32 and 15."""
    rng = np.random.default_rng(3)
    for hw in [32, 15]:
        x = rand(rng, 2, 8, hw, hw)
        got = max_pool2d_pallas(x, k=3, stride=2)
        expect = ref.max_pool2d_ref(x, k=3, stride=2)
        assert got.shape == expect.shape
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 3), c=st.integers(1, 8), h=st.integers(1, 12), w=st.integers(1, 12),
    seed=st.integers(0, 2**31),
)
def test_global_avg_pool_matches_ref(n, c, h, w, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, h, w)
    np.testing.assert_allclose(
        global_avg_pool_pallas(x), ref.global_avg_pool_ref(x), rtol=1e-4, atol=1e-5
    )


# ---- relu / softmax / quant ------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    dims=st.lists(st.integers(1, 20), min_size=1, max_size=4),
    seed=st.integers(0, 2**31),
)
def test_relu_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, *dims)
    np.testing.assert_array_equal(relu_pallas(x), ref.relu_ref(x))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    b=st.integers(1, 200), c=st.integers(1, 32), seed=st.integers(0, 2**31)
)
def test_softmax_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, c) * 3.0
    got = softmax_pallas(x)
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.sum(np.asarray(got), axis=-1), 1.0, rtol=1e-5)


def test_softmax_large_logits_stable():
    x = jnp.asarray([[1000.0, 1001.0, 999.0]])
    got = np.asarray(softmax_pallas(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got.sum(), 1.0, rtol=1e-5)


def test_quantize_symmetric_error_bound():
    rng = np.random.default_rng(11)
    x = rand(rng, 64, 64)
    xq = quantize_symmetric(x, bits=8)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(xq - x))) <= scale * 0.5 + 1e-6


@hypothesis.settings(**SETTINGS)
@hypothesis.given(bits=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31))
def test_fake_quant_matmul_error_shrinks_with_bits(bits, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, 24, 36), rand(rng, 36, 16)
    exact = np.asarray(ref.matmul_ref(x, y))
    got = np.asarray(fake_quant_matmul_pallas(x, y, bits=bits))
    rel = np.abs(got - exact).mean() / (np.abs(exact).mean() + 1e-9)
    # Coarse bound: mean relative error well under 2^-(bits-4).
    assert rel < 2.0 ** -(bits - 4), f"bits={bits} rel={rel}"
