"""Caffe exporter tests (schema + numeric fidelity)."""

import json

import numpy as np
import pytest

from compile.export_caffe import export_caffe_json
from compile.model import Architecture, Layer, char_cnn, lenet


def test_lenet_exports_caffe_schema():
    arch = lenet()
    params = arch.init_params(0)
    doc = export_caffe_json(arch, params)
    assert doc["framework"] == "caffe"
    assert doc["input_dim"] == [1, 1, 28, 28]
    types = [l["type"] for l in doc["layers"]]
    # flatten dropped; conv/relu/pool/ip/softmax present in Caffe vocabulary
    assert "Convolution" in types and "InnerProduct" in types and "Softmax" in types
    assert "Flatten" not in types
    # Blob shapes follow Caffe [out, in, k, k] convention.
    conv1 = doc["layers"][0]
    assert conv1["blobs"][0]["shape"] == [20, 1, 5, 5]
    assert conv1["blobs"][1]["shape"] == [20]


def test_export_is_json_serializable_and_faithful():
    arch = lenet()
    params = arch.init_params(1)
    doc = export_caffe_json(arch, params)
    text = json.dumps(doc)  # must not raise
    back = json.loads(text)
    w = np.array(back["layers"][0]["blobs"][0]["data"], dtype=np.float32)
    np.testing.assert_allclose(
        w, np.asarray(params["conv1.w"]).reshape(-1), rtol=1e-6, atol=1e-7
    )


def test_conv1d_models_rejected():
    arch = char_cnn()
    with pytest.raises(ValueError):
        export_caffe_json(arch, arch.init_params(0))


def test_unsupported_layer_rejected():
    arch = Architecture("bad", [1, 8, 8], [Layer("p", "max_pool1d", k=2, stride=2)])
    with pytest.raises(ValueError):
        export_caffe_json(arch, {})
