"""AOT path tests: DLKW container, HLO text emission, manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dlkw
from compile.aot import to_hlo_text
from compile.model import lenet, forward


def test_dlkw_round_trip():
    rng = np.random.default_rng(0)
    params = {
        "conv1.w": rng.normal(size=(4, 3, 3, 3)).astype(np.float32),
        "conv1.b": rng.normal(size=(4,)).astype(np.float32),
    }
    back = dlkw.read_dlkw(dlkw.write_dlkw(params))
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_dlkw_header_is_valid_json():
    params = {"w": np.ones((2, 2), np.float32)}
    blob = dlkw.write_dlkw(params)
    assert blob[:4] == b"DLKW"
    header_len = int.from_bytes(blob[8:12], "little")
    header = json.loads(blob[12 : 12 + header_len])
    assert header[0]["name"] == "w"
    assert header[0]["dtype"] == "f32"
    assert header[0]["shape"] == [2, 2]


def test_dlkw_rejects_garbage():
    with pytest.raises(ValueError):
        dlkw.read_dlkw(b"NOPE" + b"\0" * 100)


def test_hlo_text_emission_small_model():
    """Lower a tiny pallas-backed graph and check the HLO text shape."""

    def fn(x, y):
        return (jnp.dot(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text
    # Parameters appear (interchange contract with the rust loader).
    assert "parameter(0)" in text and "parameter(1)" in text


def test_lenet_forward_lowering_has_all_params():
    arch = lenet()
    params = arch.init_params(0)
    order = [n for n, _ in arch.parameters()]

    def fn(x, *flat):
        p = dict(zip(order, flat))
        return (forward(arch, p, x, use_pallas=True),)

    x_spec = jax.ShapeDtypeStruct((1, 1, 28, 28), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in order]
    text = to_hlo_text(jax.jit(fn).lower(x_spec, *p_specs))
    # input + 8 parameter tensors.
    assert f"parameter({len(order)})" in text
    assert "parameter(" + str(len(order) + 1) + ")" not in text


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, ".stamp")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_complete():
    """Every exported model dir has manifest + weights + all HLO batches."""
    models_dir = os.path.join(ARTIFACTS, "models")
    assert os.path.isdir(models_dir)
    for model_id in os.listdir(models_dir):
        mdir = os.path.join(models_dir, model_id)
        with open(os.path.join(mdir, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["format"] == "dlk-model/1"
        assert manifest["id"] == model_id
        for batch in manifest["aot_batches"]:
            hlo = os.path.join(mdir, f"model_b{batch}.hlo.txt")
            assert os.path.exists(hlo), hlo
            with open(hlo) as f:
                head = f.read(200)
            assert "HloModule" in head
        # Weights parse and match the declared sha.
        import hashlib

        with open(os.path.join(mdir, "weights.dlkw"), "rb") as f:
            blob = f.read()
        assert hashlib.sha256(blob).hexdigest() == manifest["weights_sha256"]
        weights = dlkw.read_dlkw(blob)
        labels = manifest["labels"]
        arch = manifest["architecture"]
        assert arch["layers"], model_id
        assert len(labels) > 0
        assert len(weights) > 0
