"""L2 tests: architecture IR, shape bookkeeping, forward-path parity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import ZOO, char_cnn, forward, lenet, logits_forward, nin_cifar10


def test_lenet_shapes_and_params():
    arch = lenet()
    shapes = arch.shapes()
    assert shapes[0] == [1, 28, 28]
    assert shapes[-1] == [10]
    assert arch.num_classes() == 10
    # Same canonical count as the rust zoo test.
    total = sum(int(np.prod(s)) for _, s in arch.parameters())
    assert total == 520 + 25050 + 400500 + 5010


def test_nin_matches_paper_depth():
    arch = nin_cifar10()
    assert arch.shapes()[-1] == [10]
    # 9 convs, ~966k params.
    convs = [l for l in arch.layers if l.type == "conv2d"]
    assert len(convs) == 9
    total = sum(int(np.prod(s)) for _, s in arch.parameters())
    assert 900_000 < total < 1_050_000


def test_char_cnn_shapes():
    arch = char_cnn()
    assert arch.shapes()[0] == [64, 256]
    assert arch.num_classes() == 4


@pytest.mark.parametrize("model_id", list(ZOO))
def test_init_params_match_declared_shapes(model_id):
    arch = ZOO[model_id]()
    params = arch.init_params(0)
    declared = dict(arch.parameters())
    assert set(params) == set(declared)
    for name, arr in params.items():
        assert tuple(arr.shape) == tuple(declared[name]), name


def test_forward_pallas_vs_jnp_parity_lenet():
    arch = lenet()
    params = arch.init_params(1)
    x, _ = data.glyphs(3, seed=5)
    a = np.asarray(forward(arch, params, jnp.asarray(x), use_pallas=True))
    b = np.asarray(forward(arch, params, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_forward_pallas_vs_jnp_parity_char_cnn():
    arch = char_cnn()
    params = arch.init_params(2)
    x, _ = data.chars(2, seed=5)
    a = np.asarray(forward(arch, params, jnp.asarray(x), use_pallas=True))
    b = np.asarray(forward(arch, params, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_forward_outputs_probabilities():
    arch = lenet()
    params = arch.init_params(3)
    x, _ = data.glyphs(4, seed=6)
    probs = np.asarray(forward(arch, params, jnp.asarray(x), use_pallas=False))
    assert probs.shape == (4, 10)
    np.testing.assert_allclose(probs.sum(axis=-1), 1.0, rtol=1e-5)
    assert (probs >= 0).all()


def test_logits_forward_drops_softmax():
    arch = lenet()
    params = arch.init_params(4)
    x, _ = data.glyphs(2, seed=7)
    logits = np.asarray(logits_forward(arch, params, jnp.asarray(x)))
    # Logits should NOT be normalized.
    assert not np.allclose(logits.sum(axis=-1), 1.0)


def test_manifest_json_matches_rust_schema():
    arch = lenet()
    j = arch.to_json()
    assert j["name"] == "lenet-mnist"
    assert j["input"] == [1, 28, 28]
    types = [l["type"] for l in j["layers"]]
    assert types[0] == "conv2d" and types[-1] == "softmax"
    conv = j["layers"][0]
    assert set(conv) == {"name", "type", "out_ch", "k", "stride", "pad"}
