"""Procedural dataset tests: determinism, ranges, learnability signal."""

import numpy as np

from compile import data


def test_glyphs_shapes_and_range():
    x, y = data.glyphs(16, seed=3)
    assert x.shape == (16, 1, 28, 28)
    assert y.shape == (16,)
    assert x.dtype == np.float32
    assert (x >= 0).all() and (x <= 1).all()
    assert ((y >= 0) & (y < 10)).all()


def test_glyphs_deterministic():
    a = data.glyphs(8, seed=42)
    b = data.glyphs(8, seed=42)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = data.glyphs(8, seed=43)
    assert not np.array_equal(a[0], c[0])


def test_glyphs_classes_distinguishable():
    """Mean images of different digits must differ meaningfully."""
    x, y = data.glyphs(500, seed=1)
    means = [x[y == d].mean(axis=0) for d in range(10) if (y == d).sum() > 3]
    assert len(means) == 10
    dists = []
    for i in range(len(means)):
        for j in range(i + 1, len(means)):
            dists.append(np.abs(means[i] - means[j]).mean())
    assert min(dists) > 0.005, f"classes overlap: {min(dists)}"


def test_textures_shapes_and_determinism():
    x, y = data.textures(12, seed=9)
    assert x.shape == (12, 3, 32, 32)
    assert ((y >= 0) & (y < 10)).all()
    x2, y2 = data.textures(12, seed=9)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_textures_all_classes_generate():
    x, y = data.textures(200, seed=5)
    assert len(np.unique(y)) == 10
    assert np.isfinite(x).all()


def test_chars_one_hot():
    x, y = data.chars(6, seed=2)
    assert x.shape == (6, 64, 256)
    assert ((y >= 0) & (y < 4)).all()
    # Each position has at most one hot row.
    col_sums = x.sum(axis=1)
    assert (col_sums <= 1.0 + 1e-6).all()
    # Documents are non-empty.
    assert (x.sum(axis=(1, 2)) > 50).all()


def test_chars_deterministic():
    a = data.chars(4, seed=7)
    b = data.chars(4, seed=7)
    np.testing.assert_array_equal(a[0], b[0])
