"""Layer-2 JAX model: layer IR + forward pass.

Mirrors the rust `model::Architecture` IR exactly (same layer vocabulary,
same parameter naming `<layer>.w` / `<layer>.b`, same manifest JSON) so
that the Rust coordinator, the rust CPU reference backend and these JAX
graphs agree on what a model is.

`forward(arch, params, x, use_pallas=True)` is the graph that
`aot.py` lowers to HLO; with `use_pallas=False` it runs on stock jnp ops
(used by the trainer, where interpret-mode Pallas would be needlessly
slow, and as an L2-level cross-check of the kernels).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    avg_pool2d_pallas,
    conv1d_pallas,
    conv2d_pallas,
    global_avg_pool_pallas,
    max_pool2d_pallas,
    relu_pallas,
    softmax_pallas,
)
from .kernels import ref
from .kernels.matmul import dense_pallas


@dataclasses.dataclass(frozen=True)
class Layer:
    """One layer: name + type + attributes (mirror of rust LayerKind)."""

    name: str
    type: str
    out_ch: int = 0
    k: int = 0
    stride: int = 1
    pad: int = 0
    out: int = 0
    rate: float = 0.5

    def to_json(self):
        d = {"name": self.name, "type": self.type}
        if self.type in ("conv2d", "conv1d"):
            d.update(out_ch=self.out_ch, k=self.k, stride=self.stride, pad=self.pad)
        elif self.type in ("max_pool2d", "avg_pool2d"):
            d.update(k=self.k, stride=self.stride, pad=self.pad)
        elif self.type == "max_pool1d":
            d.update(k=self.k, stride=self.stride)
        elif self.type == "dense":
            d.update(out=self.out)
        elif self.type == "dropout":
            d.update(rate=self.rate)
        return d


@dataclasses.dataclass
class Architecture:
    """Sequential model IR (mirror of rust `model::Architecture`)."""

    name: str
    input: list  # [C,H,W] or [C,L], no batch dim
    layers: list

    def to_json(self):
        return {
            "name": self.name,
            "input": list(self.input),
            "layers": [l.to_json() for l in self.layers],
        }

    # ---- shape / parameter bookkeeping (mirrors rust exactly) ----------

    def shapes(self):
        """Shape after every layer, batch dim excluded."""
        out = [list(self.input)]
        cur = list(self.input)
        for l in self.layers:
            cur = _next_shape(cur, l)
            out.append(list(cur))
        return out

    def num_classes(self):
        last = self.shapes()[-1]
        assert len(last) == 1, f"output is not a class vector: {last}"
        return last[0]

    def parameters(self):
        """[(name, shape)] in execution order."""
        shapes = self.shapes()
        params = []
        for i, l in enumerate(self.layers):
            inp = shapes[i]
            if l.type == "conv2d":
                params.append((f"{l.name}.w", (l.out_ch, inp[0], l.k, l.k)))
                params.append((f"{l.name}.b", (l.out_ch,)))
            elif l.type == "conv1d":
                params.append((f"{l.name}.w", (l.out_ch, inp[0], l.k)))
                params.append((f"{l.name}.b", (l.out_ch,)))
            elif l.type == "dense":
                in_f = int(np.prod(inp))
                params.append((f"{l.name}.w", (l.out, in_f)))
                params.append((f"{l.name}.b", (l.out,)))
        return params

    def init_params(self, seed=0):
        """He-initialized parameter dict."""
        rng = np.random.default_rng(seed)
        params = {}
        for name, shape in self.parameters():
            if name.endswith(".b"):
                params[name] = jnp.zeros(shape, jnp.float32)
            else:
                fan_in = int(np.prod(shape[1:])) or 1
                scale = math.sqrt(2.0 / fan_in)
                params[name] = jnp.asarray(
                    rng.normal(0.0, scale, size=shape), jnp.float32
                )
        return params


def _pool_out(size, k, stride, pad):
    o = max(0, (size + 2 * pad - k + stride - 1)) // stride + 1
    # Clamp: the last window must start strictly inside `size + pad`
    # (applied unconditionally, unlike Caffe's pad-only guard, so the
    # degenerate stride>k pad=0 case cannot produce an empty window).
    if o > 1 and (o - 1) * stride >= size + pad:
        o -= 1
    return o


def _next_shape(inp, l: Layer):
    if l.type == "conv2d":
        oh = (inp[1] + 2 * l.pad - l.k) // l.stride + 1
        ow = (inp[2] + 2 * l.pad - l.k) // l.stride + 1
        return [l.out_ch, oh, ow]
    if l.type == "conv1d":
        return [l.out_ch, (inp[1] + 2 * l.pad - l.k) // l.stride + 1]
    if l.type in ("relu", "dropout"):
        return inp
    if l.type in ("max_pool2d", "avg_pool2d"):
        return [inp[0], _pool_out(inp[1], l.k, l.stride, l.pad), _pool_out(inp[2], l.k, l.stride, l.pad)]
    if l.type == "max_pool1d":
        return [inp[0], (inp[1] - l.k) // l.stride + 1]
    if l.type == "global_avg_pool":
        return [inp[0]]
    if l.type == "dense":
        return [l.out]
    if l.type == "flatten":
        return [int(np.prod(inp))]
    if l.type == "softmax":
        assert len(inp) == 1, f"softmax expects a vector, got {inp}"
        return inp
    raise ValueError(f"unknown layer type {l.type}")


def forward(arch: Architecture, params: dict, x, *, use_pallas: bool = True):
    """Run the model. `x` is `[batch] + arch.input`.

    With `use_pallas=True` all FLOP-bearing ops go through the Layer-1
    Pallas kernels; otherwise stock jnp ops (identical semantics).
    """
    for l in arch.layers:
        if l.type == "conv2d":
            w, b = params[f"{l.name}.w"], params[f"{l.name}.b"]
            if use_pallas:
                x = conv2d_pallas(x, w, b, stride=l.stride, pad=l.pad)
            else:
                x = ref.conv2d_ref(x, w, b, stride=l.stride, pad=l.pad)
        elif l.type == "conv1d":
            w, b = params[f"{l.name}.w"], params[f"{l.name}.b"]
            if use_pallas:
                x = conv1d_pallas(x, w, b, stride=l.stride, pad=l.pad)
            else:
                x = ref.conv1d_ref(x, w, b, stride=l.stride, pad=l.pad)
        elif l.type == "relu":
            x = relu_pallas(x) if use_pallas else ref.relu_ref(x)
        elif l.type == "max_pool2d":
            if use_pallas:
                x = max_pool2d_pallas(x, k=l.k, stride=l.stride, pad=l.pad)
            else:
                x = _pool2d_jnp(x, l.k, l.stride, l.pad, "max")
        elif l.type == "avg_pool2d":
            if use_pallas:
                x = avg_pool2d_pallas(x, k=l.k, stride=l.stride, pad=l.pad)
            else:
                x = _pool2d_jnp(x, l.k, l.stride, l.pad, "avg")
        elif l.type == "max_pool1d":
            x = _pool1d_jnp(x, l.k, l.stride)
        elif l.type == "global_avg_pool":
            if use_pallas:
                x = global_avg_pool_pallas(x)
            else:
                x = ref.global_avg_pool_ref(x)
        elif l.type == "dense":
            w, b = params[f"{l.name}.w"], params[f"{l.name}.b"]
            x = dense_pallas(x, w, b) if use_pallas else ref.dense_ref(x, w, b)
        elif l.type == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif l.type == "dropout":
            pass  # inference no-op
        elif l.type == "softmax":
            x = softmax_pallas(x) if use_pallas else ref.softmax_ref(x)
        else:
            raise ValueError(f"unknown layer type {l.type}")
    return x


def logits_forward(arch: Architecture, params: dict, x):
    """Training-path forward: jnp ops only, stops before softmax."""
    sub = Architecture(arch.name, arch.input, [l for l in arch.layers if l.type != "softmax"])
    return forward(sub, params, x, use_pallas=False)


def _pool2d_jnp(x, k, stride, pad, mode):
    """Ceil-mode Caffe pooling on stock jnp (trainer path)."""
    n, c, h, w = x.shape
    oh = _pool_out(h, k, stride, pad)
    ow = _pool_out(w, k, stride, pad)
    ph = max(h + 2 * pad, (oh - 1) * stride + k)
    pw = max(w + 2 * pad, (ow - 1) * stride + k)
    neg = jnp.float32(-3.0e38)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, ph - h - pad), (pad, pw - w - pad)))
    acc = None
    cnt = None
    for ky in range(k):
        for kx in range(k):
            ys = ky + stride * np.arange(oh)
            xs = kx + stride * np.arange(ow)
            cell = xp[:, :, ys[:, None], xs[None, :]]
            valid = (
                (ys[:, None] >= pad)
                & (ys[:, None] < pad + h)
                & (xs[None, :] >= pad)
                & (xs[None, :] < pad + w)
            )
            vm = jnp.asarray(valid)[None, None]
            if mode == "max":
                cell = jnp.where(vm, cell, neg)
                acc = cell if acc is None else jnp.maximum(acc, cell)
            else:
                cell = jnp.where(vm, cell, 0.0)
                acc = cell if acc is None else acc + cell
                c1 = vm.astype(jnp.float32)
                cnt = c1 if cnt is None else cnt + c1
    if mode == "max":
        return acc
    return acc / jnp.maximum(cnt, 1.0)


def _pool1d_jnp(x, k, stride):
    n, c, l = x.shape
    ol = (l - k) // stride + 1
    acc = None
    for ki in range(k):
        cell = x[:, :, ki : ki + (ol - 1) * stride + 1 : stride]
        acc = cell if acc is None else jnp.maximum(acc, cell)
    return acc


# ---- zoo builders (must mirror rust/src/model/zoo.rs exactly) -------------


def lenet() -> Architecture:
    """LeNet on 28x28 grayscale (paper: Theano-trained LeNet / MNIST)."""
    L = Layer
    return Architecture(
        "lenet-mnist",
        [1, 28, 28],
        [
            L("conv1", "conv2d", out_ch=20, k=5, stride=1, pad=0),
            L("relu1", "relu"),
            L("pool1", "max_pool2d", k=2, stride=2, pad=0),
            L("conv2", "conv2d", out_ch=50, k=5, stride=1, pad=0),
            L("relu2", "relu"),
            L("pool2", "max_pool2d", k=2, stride=2, pad=0),
            L("flatten", "flatten"),
            L("fc1", "dense", out=500),
            L("relu3", "relu"),
            L("fc2", "dense", out=10),
            L("softmax", "softmax"),
        ],
    )


def nin_cifar10() -> Architecture:
    """Network-in-Network / CIFAR-10 — the paper's 20-layer E1 network."""
    L = Layer
    return Architecture(
        "nin-cifar10",
        [3, 32, 32],
        [
            L("conv1", "conv2d", out_ch=192, k=5, stride=1, pad=2),
            L("relu1", "relu"),
            L("cccp1", "conv2d", out_ch=160, k=1, stride=1, pad=0),
            L("relu_cccp1", "relu"),
            L("cccp2", "conv2d", out_ch=96, k=1, stride=1, pad=0),
            L("relu_cccp2", "relu"),
            L("pool1", "max_pool2d", k=3, stride=2, pad=0),
            L("drop1", "dropout", rate=0.5),
            L("conv2", "conv2d", out_ch=192, k=5, stride=1, pad=2),
            L("relu2", "relu"),
            L("cccp3", "conv2d", out_ch=192, k=1, stride=1, pad=0),
            L("relu_cccp3", "relu"),
            L("cccp4", "conv2d", out_ch=192, k=1, stride=1, pad=0),
            L("relu_cccp4", "relu"),
            L("pool2", "avg_pool2d", k=3, stride=2, pad=0),
            L("drop2", "dropout", rate=0.5),
            L("conv3", "conv2d", out_ch=192, k=3, stride=1, pad=1),
            L("relu3", "relu"),
            L("cccp5", "conv2d", out_ch=192, k=1, stride=1, pad=0),
            L("relu_cccp5", "relu"),
            L("cccp6", "conv2d", out_ch=10, k=1, stride=1, pad=0),
            L("relu_cccp6", "relu"),
            L("gap", "global_avg_pool"),
            L("softmax", "softmax"),
        ],
    )


def char_cnn() -> Architecture:
    """Character-level 1-D CNN (Zhang & LeCun; paper roadmap item 9)."""
    L = Layer
    return Architecture(
        "char-cnn",
        [64, 256],
        [
            L("conv1", "conv1d", out_ch=128, k=7, stride=1, pad=0),
            L("relu1", "relu"),
            L("pool1", "max_pool1d", k=3, stride=3),
            L("conv2", "conv1d", out_ch=128, k=7, stride=1, pad=0),
            L("relu2", "relu"),
            L("pool2", "max_pool1d", k=3, stride=3),
            L("conv3", "conv1d", out_ch=128, k=3, stride=1, pad=0),
            L("relu3", "relu"),
            L("pool3", "max_pool1d", k=3, stride=3),
            L("flatten", "flatten"),
            L("fc1", "dense", out=256),
            L("relu4", "relu"),
            L("drop1", "dropout", rate=0.5),
            L("fc2", "dense", out=4),
            L("softmax", "softmax"),
        ],
    )


ZOO = {
    "lenet-mnist": lenet,
    "nin-cifar10": nin_cifar10,
    "char-cnn": char_cnn,
}
