"""Python writer/reader for the DLKW binary weights container.

Byte-compatible with `rust/src/model/weights.rs`:

    magic "DLKW" | version u32 LE | header_len u32 LE | header JSON | blob

Header entries: {"name", "dtype", "shape", "offset", "len", "scale"?}.
Only f32 is emitted from Python (storage-dtype experiments happen on the
rust side); the reader handles f32 for round-trip tests.
"""

import json
import struct

import numpy as np

MAGIC = b"DLKW"
VERSION = 1


def write_dlkw(params: dict) -> bytes:
    """Serialize {name: np.ndarray} to DLKW bytes (f32 storage)."""
    header = []
    blob = bytearray()
    for name in sorted(params):
        arr = np.asarray(params[name], dtype=np.float32)
        offset = len(blob)
        payload = arr.tobytes()  # C-order little-endian on all our hosts
        blob.extend(payload)
        header.append(
            {
                "name": name,
                "dtype": "f32",
                "shape": list(arr.shape),
                "offset": offset,
                "len": len(payload),
            }
        )
    header_bytes = json.dumps(header, separators=(",", ":")).encode("utf-8")
    return (
        MAGIC
        + struct.pack("<I", VERSION)
        + struct.pack("<I", len(header_bytes))
        + header_bytes
        + bytes(blob)
    )


def read_dlkw(data: bytes) -> dict:
    """Parse DLKW bytes back to {name: np.ndarray} (f32 only)."""
    if data[:4] != MAGIC:
        raise ValueError("bad DLKW magic")
    version, header_len = struct.unpack_from("<II", data, 4)
    if version != VERSION:
        raise ValueError(f"unsupported DLKW version {version}")
    header = json.loads(data[12 : 12 + header_len].decode("utf-8"))
    blob = data[12 + header_len :]
    out = {}
    for entry in header:
        if entry["dtype"] != "f32":
            raise ValueError(f"python reader only supports f32, got {entry['dtype']}")
        start, length = entry["offset"], entry["len"]
        arr = np.frombuffer(blob[start : start + length], dtype="<f4")
        out[entry["name"]] = arr.reshape(entry["shape"]).copy()
    return out
