"""Simulated low-precision matmul (paper roadmap item 2: "use lower
resolution on floating point in order to increase performance and support
larger models", citing Gupta et al. and Warden's eight-bit argument).

`fake_quant_matmul_pallas` quantizes both operands to symmetric int8
grids before the MXU matmul — the standard way to measure the *accuracy*
cost of an int8 deployment while the arithmetic itself stays f32 in
interpret mode. E7 sweeps this against f32/f16 storage.
"""

import jax.numpy as jnp

from .matmul import matmul_pallas


def quantize_symmetric(x, bits=8):
    """Fake-quantize to a symmetric `bits`-bit grid: returns x_hat."""
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    return jnp.round(x / scale).clip(-qmax, qmax) * scale


def fake_quant_matmul_pallas(x, y, *, bits=8):
    """Matmul with both operands fake-quantized to `bits` bits."""
    return matmul_pallas(quantize_symmetric(x, bits), quantize_symmetric(y, bits))
