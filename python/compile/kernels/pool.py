"""Pooling kernels (max / average / global-average) over NCHW.

Caffe pooling semantics to match the rust reference backend: ceil output
sizing, overhanging windows clipped to the input, padding excluded from
average counts.

The Pallas kernel grids over (plane-tile) where each step holds one
``[bp, h, w]`` stack of image planes in VMEM and reduces its windows with
statically unrolled shifted-slice maxima/sums — the TPU-friendly shape of
the paper's per-threadgroup pooling shader (vector ops over lanes instead
of scalar window walks).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_out(size, k, stride, pad):
    """Caffe ceil-mode output size with the pad clamp: the last window must
    start strictly inside `size + pad`."""
    o = max(0, (size + 2 * pad - k + stride - 1)) // stride + 1
    # Clamp: the last window must start strictly inside `size + pad`
    # (applied unconditionally, unlike Caffe's pad-only guard, so the
    # degenerate stride>k pad=0 case cannot produce an empty window).
    if o > 1 and (o - 1) * stride >= size + pad:
        o -= 1
    return o


def _pool_kernel(x_ref, o_ref, *, k, stride, pad, h, w, oh, ow, is_max):
    """Reduce one stack of planes. x_ref: [bp, ph, pw] (pre-padded)."""
    x = x_ref[...]
    neg = jnp.float32(-3.0e38)
    if is_max:
        acc = jnp.full(o_ref.shape, neg, dtype=jnp.float32)
    else:
        acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
        cnt = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for ky in range(k):
        for kx in range(k):
            # Window cell (ky,kx) contributes x[:, oy*stride+ky, ox*stride+kx]
            # where the index is within the *padded* plane; validity mask
            # marks cells that fall on real (unpadded, in-bounds) pixels.
            ys = ky + stride * jnp.arange(oh)
            xs = kx + stride * jnp.arange(ow)
            cell = x[:, ys[:, None], xs[None, :]]
            valid = (
                (ys[:, None] >= pad)
                & (ys[:, None] < pad + h)
                & (xs[None, :] >= pad)
                & (xs[None, :] < pad + w)
            )
            if is_max:
                acc = jnp.maximum(acc, jnp.where(valid[None], cell, neg))
            else:
                acc = acc + jnp.where(valid[None], cell, 0.0)
                cnt = cnt + valid[None].astype(jnp.float32)
    if is_max:
        o_ref[...] = acc
    else:
        o_ref[...] = acc / jnp.maximum(cnt, 1.0)


def _pool2d(x, k, stride, pad, is_max):
    n, c, h, w = x.shape
    oh = _pool_out(h, k, stride, pad)
    ow = _pool_out(w, k, stride, pad)
    planes = x.reshape(n * c, h, w).astype(jnp.float32)
    # Pad spatially so every window index is in range: the last window
    # starts at (o-1)*stride and spans k.
    ph = max(h + 2 * pad, (oh - 1) * stride + k)
    pw = max(w + 2 * pad, (ow - 1) * stride + k)
    planes = jnp.pad(planes, ((0, 0), (pad, ph - h - pad), (pad, pw - w - pad)))

    # Plane tile: whole spatial extent, bp planes per grid step. Fill a
    # ~4 MiB VMEM budget per step — grid steps are while-loop iterations in
    # the lowered HLO, so fewer/fatter steps win (see matmul.py).
    plane_bytes = 4 * ph * pw
    bp = max(8, min(planes.shape[0], (4 * 1024 * 1024) // max(plane_bytes, 1)))
    gp = -(-planes.shape[0] // bp)
    planes = jnp.pad(planes, ((0, gp * bp - planes.shape[0]), (0, 0), (0, 0)))

    kernel = functools.partial(
        _pool_kernel, k=k, stride=stride, pad=pad, h=h, w=w, oh=oh, ow=ow, is_max=is_max
    )
    out = pl.pallas_call(
        kernel,
        grid=(gp,),
        in_specs=[pl.BlockSpec((bp, ph, pw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bp, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gp * bp, oh, ow), jnp.float32),
        interpret=True,
    )(planes)
    return out[: n * c].reshape(n, c, oh, ow)


def max_pool2d_pallas(x, *, k, stride, pad=0):
    """Max pooling, Caffe ceil semantics."""
    return _pool2d(x, k, stride, pad, is_max=True)


def avg_pool2d_pallas(x, *, k, stride, pad=0):
    """Average pooling with in-bounds divisor (Caffe AVE, pad-excluded)."""
    return _pool2d(x, k, stride, pad, is_max=False)


def global_avg_pool_pallas(x):
    """NCHW -> [N, C] global average (NIN classifier head)."""
    n, c, h, w = x.shape
    return avg_pool2d_pallas(x, k=max(h, w), stride=max(h, w), pad=0).reshape(n, c)
