"""Pure-jnp oracles for every Pallas kernel.

These use only stock `jax.lax`/`jnp` ops (no Pallas) and are the
correctness contract: pytest + hypothesis assert each kernel matches its
oracle across swept shapes/strides/paddings. They mirror the semantics of
the rust CPU backend (`rust/src/nn/`) exactly — Caffe cross-correlation,
ceil-mode pooling with pad-excluded averages.
"""

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(x, y):
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def dense_ref(x, w, b):
    return matmul_ref(x, w.T) + b[None, :]


def conv2d_ref(x, w, b, *, stride=1, pad=0):
    """NCHW cross-correlation via lax.conv_general_dilated."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def conv1d_ref(x, w, b, *, stride=1, pad=0):
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride,),
        padding=((pad, pad),),
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    if b is not None:
        y = y + b[None, :, None]
    return y


def _pool_out(size, k, stride, pad):
    o = max(0, (size + 2 * pad - k + stride - 1)) // stride + 1
    # Clamp: the last window must start strictly inside `size + pad`
    # (applied unconditionally, unlike Caffe's pad-only guard, so the
    # degenerate stride>k pad=0 case cannot produce an empty window).
    if o > 1 and (o - 1) * stride >= size + pad:
        o -= 1
    return o


def _pool2d_ref_np(x, k, stride, pad, mode):
    """Numpy reference with explicit Caffe semantics (ceil, clip, pad-excl)."""
    x = np.asarray(x, dtype=np.float32)
    n, c, h, w = x.shape
    oh = _pool_out(h, k, stride, pad)
    ow = _pool_out(w, k, stride, pad)
    out = np.zeros((n, c, oh, ow), dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            y0 = oy * stride - pad
            x0 = ox * stride - pad
            ys = slice(max(0, y0), min(h, y0 + k))
            xs = slice(max(0, x0), min(w, x0 + k))
            window = x[:, :, ys, xs]
            if window.size == 0:
                continue
            if mode == "max":
                out[:, :, oy, ox] = window.max(axis=(2, 3))
            else:
                out[:, :, oy, ox] = window.mean(axis=(2, 3))
    return jnp.asarray(out)


def max_pool2d_ref(x, *, k, stride, pad=0):
    return _pool2d_ref_np(x, k, stride, pad, "max")


def avg_pool2d_ref(x, *, k, stride, pad=0):
    return _pool2d_ref_np(x, k, stride, pad, "avg")


def global_avg_pool_ref(x):
    return jnp.mean(x.astype(jnp.float32), axis=(2, 3))


def relu_ref(x):
    return jnp.maximum(x.astype(jnp.float32), 0.0)


def softmax_ref(x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1)


def fake_quant_ref(x, bits=8):
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    return jnp.round(x / scale).clip(-qmax, qmax) * scale
