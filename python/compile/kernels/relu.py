"""Rectifier kernel — the literal analog of the paper's Figure 3 Metal
shader (`rectifier_linear`, `max(0.0, x)` elementwise).

Gridded over leading-dim tiles so an arbitrarily large activation tensor
streams through VMEM tile by tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128x128 f32 tile = 64 KiB of VMEM.
TILE = 128


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def relu_pallas(x):
    """Elementwise `max(0, x)` for any shape (flattened to 2-D tiles).

    Row-tile height adapts to a ~4 MiB VMEM budget so typical CNN
    activation tensors run in one or two grid steps (grid steps lower to
    while-loop iterations — see matmul.py)."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # View as [rows, TILE] columns.
    rows = -(-n // TILE)
    padded = jnp.pad(flat, (0, rows * TILE - n)).reshape(rows, TILE)
    # Rows per grid step under the budget.
    tile_rows = max(TILE, min(rows, (4 * 1024 * 1024) // (4 * TILE)))
    grid = -(-rows // tile_rows)
    padded = jnp.pad(padded, ((0, grid * tile_rows - rows), (0, 0)))

    out = pl.pallas_call(
        _relu_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile_rows, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_rows, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, jnp.float32),
        interpret=True,
    )(padded.astype(jnp.float32))
    return out.reshape(-1)[:n].reshape(shape)
