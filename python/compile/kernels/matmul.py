"""Tiled MXU matmul — the workhorse Pallas kernel.

The paper's Metal convolution shader assigns an output tile per
threadgroup and loops scalar multiply-adds. On TPU the same computation
should be one `jnp.dot` per (bm x bn) output tile so it lands on the MXU
systolic array; the BlockSpec index maps below are the HBM->VMEM schedule
(grid dim 2 walks the K dimension, accumulating into the resident output
tile -- the double-buffering analog of Metal's threadgroup staging).

VMEM budget per grid step (defaults, f32):
    x tile  bm*bk*4 = 128*512*4   = 256 KiB
    y tile  bk*bn*4 = 512*128*4   = 256 KiB
    o tile  bm*bn*4 = 128*128*4   =  64 KiB
    total ~576 KiB  << 16 MiB VMEM  (see DESIGN.md SSPerf)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile caps: multiples of the 128-lane MXU dimension. Actual tiles
# are chosen per problem by `_pick_tiles` to fill (but not bust) the VMEM
# budget with as FEW grid steps as possible — each grid step costs a
# while-loop iteration in the lowered HLO, so on small CNN-layer GEMMs the
# step count, not the FLOPs, dominates latency (EXPERIMENTS.md §Perf).
BM, BN, BK = 256, 2048, 2048

# Per-step VMEM budget (bytes): x-tile + y-tile + o-tile must fit well
# inside the 16 MiB VMEM of a TPU core, leaving room for double-buffering.
VMEM_BUDGET = 6 * 1024 * 1024


def _round_up(x, mult):
    return -(-x // mult) * mult


def _pick_tiles(m, k, n, bm_cap, bn_cap, bk_cap):
    """Choose (bm, bn, bk): whole dims when they fit, shrinking toward the
    caps/VMEM budget. Tiles are padded to multiples of 8 (sublane) to stay
    MXU-friendly."""
    bm = min(_round_up(m, 8), bm_cap)
    bn = min(_round_up(n, 128), bn_cap)
    bk = min(_round_up(k, 128), bk_cap)

    def vmem(bm, bn, bk):
        return 4 * (bm * bk + bk * bn + bm * bn)

    # Shrink the largest tile dimension until the working set fits.
    while vmem(bm, bn, bk) > VMEM_BUDGET and (bn > 128 or bk > 128 or bm > 8):
        if bn >= bk and bn > 128:
            bn //= 2
        elif bk > 128:
            bk //= 2
        elif bm > 8:
            bm //= 2
        else:
            break
    return max(bm, 8), max(bn, 128), max(bk, 128)


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, rows, cols):
    """Zero-pad a 2-D array up to (rows, cols)."""
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(x, y, *, bm=BM, bn=BN, bk=BK):
    """`x[m,k] @ y[k,n]` via the tiled Pallas kernel.

    Shapes need not be tile-aligned: inputs are zero-padded to the tile
    grid and the result is sliced back. Zero padding is exact for matmul.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"matmul inner dims {k} vs {k2}")
    bm, bn, bk = _pick_tiles(m, k, n, bm, bn, bk)
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    xp = _pad_to(x.astype(jnp.float32), gm * bm, gk * bk)
    yp = _pad_to(y.astype(jnp.float32), gk * bk, gn * bn)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def dense_pallas(x, w, b):
    """Fully-connected layer: `x[batch,in] @ w.T[in,out] + b`.

    Weight layout `[out, in]` (Caffe InnerProduct / rust `model` crate
    convention).
    """
    return matmul_pallas(x, w.T) + b[None, :]
