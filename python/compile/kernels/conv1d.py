"""1-D convolution (char-CNN / NLP path, paper roadmap item 9).

Same im2col + MXU-matmul structure as :mod:`conv2d`, over ``[n, c, l]``.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas


def conv1d_pallas(x, w, b, *, stride=1, pad=0):
    """Cross-correlation over the last axis.

    Args:
        x: ``[n, c, l]``.
        w: ``[oc, c, k]``.
        b: ``[oc]`` or None.
    """
    n, c, l = x.shape
    oc, wc, k = w.shape
    if wc != c:
        raise ValueError(f"weight channels {wc} != input {c}")
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(k,),
        window_strides=(stride,),
        padding=((pad, pad),),
    )  # [n, c*k, ol]
    _, feat, ol = patches.shape
    pm = jnp.transpose(patches, (1, 0, 2)).reshape(feat, n * ol)
    ym = matmul_pallas(w.reshape(oc, feat), pm)
    y = ym.reshape(oc, n, ol).transpose(1, 0, 2)
    if b is not None:
        y = y + b[None, :, None]
    return y
