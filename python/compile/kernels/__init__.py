"""Layer-1 Pallas kernels (build-time only).

These are the reproduction of the paper's Metal shader functions --
"convolution, pooling, rectifier layer and softmax" (SS1) -- rethought for
the TPU programming model (DESIGN.md SSHardware-Adaptation):

- convolution is im2col + a *tiled MXU matmul* Pallas kernel, instead of
  Metal threadgroup scalar loops;
- BlockSpecs express the HBM<->VMEM schedule that Metal expressed with
  threadgroup dispatch;
- every kernel runs under ``interpret=True`` (CPU PJRT cannot execute
  Mosaic custom-calls) and is validated against the pure-jnp oracles in
  :mod:`ref`.
"""

from .conv1d import conv1d_pallas
from .conv2d import conv2d_pallas
from .matmul import matmul_pallas
from .pool import avg_pool2d_pallas, global_avg_pool_pallas, max_pool2d_pallas
from .quant import fake_quant_matmul_pallas, quantize_symmetric
from .relu import relu_pallas
from .softmax import softmax_pallas

__all__ = [
    "avg_pool2d_pallas",
    "conv1d_pallas",
    "conv2d_pallas",
    "fake_quant_matmul_pallas",
    "global_avg_pool_pallas",
    "matmul_pallas",
    "max_pool2d_pallas",
    "quantize_symmetric",
    "relu_pallas",
    "softmax_pallas",
]
