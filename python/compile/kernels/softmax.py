"""Row-wise softmax kernel (paper §1 operator list).

Grids over row tiles; each step holds a ``[br, classes]`` tile in VMEM and
does the max-subtract / exp / normalize dance entirely on-chip.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_pallas(x):
    """Softmax over the last axis of a ``[batch, classes]`` array."""
    b, c = x.shape
    br = 128
    gb = -(-b // br)
    # Pad rows (padded rows produce garbage we slice off; they cannot NaN
    # because exp(0-0)=1 rows normalize to uniform).
    xp = jnp.pad(x.astype(jnp.float32), ((0, gb * br - b), (0, 0)))
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(gb,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gb * br, c), jnp.float32),
        interpret=True,
    )(xp)
    return out[:b]
