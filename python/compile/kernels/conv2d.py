"""2-D convolution as im2col + the tiled MXU matmul kernel.

Hardware adaptation (DESIGN.md): the paper's Metal shader walks the
receptive field with scalar loops per threadgroup; on TPU we restructure
so the inner loop is a 128-lane matmul:

    patches = im2col(x)                # [N, C*k*k, OH*OW]  (XLA gather)
    y[oc, :] = W[oc, C*k*k] @ patches  # Pallas tiled MXU matmul

The patch extraction is pure data movement, which XLA fuses; all FLOPs go
through :func:`matmul_pallas`.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas


def conv2d_pallas(x, w, b, *, stride=1, pad=0):
    """Cross-correlation (Caffe convention) over NCHW.

    Args:
        x: input ``[n, c, h, w]``.
        w: weights ``[oc, c, k, k]``.
        b: bias ``[oc]`` or None.
        stride, pad: square stride / symmetric zero padding.

    Returns:
        ``[n, oc, oh, ow]`` f32.
    """
    n, c, h, wd = x.shape
    oc, wc, kh, kw = w.shape
    if wc != c:
        raise ValueError(f"weight in_ch {wc} != input channels {c}")
    if kh != kw:
        raise ValueError("square kernels only")
    # Patches: [n, c*k*k, oh, ow]; feature order is (c, ky, kx) — matches
    # both the Caffe blob layout and the rust im2col.
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
    )
    _, feat, oh, ow = patches.shape
    # One GEMM per batch element through the shared Pallas kernel:
    # W[oc, feat] @ P[feat, oh*ow]. Batch is folded into the N dimension of
    # a single matmul so the MXU sees one big [feat, n*oh*ow] operand.
    pm = jnp.transpose(patches, (1, 0, 2, 3)).reshape(feat, n * oh * ow)
    wm = w.reshape(oc, feat)
    ym = matmul_pallas(wm, pm)  # [oc, n*oh*ow]
    y = ym.reshape(oc, n, oh, ow).transpose(1, 0, 2, 3)
    if b is not None:
        y = y + b[None, :, None, None]
    return y
