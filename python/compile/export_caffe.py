"""Caffe-JSON exporter: the companion of the rust importer.

The paper's §3 workflow is Caffe -> JSON -> DeepLearningKit. This module
produces that JSON from a DLK `Architecture` + parameter dict — i.e. it
plays the role of the `caffe_export.py` dump script a Caffe user would
run, letting the test-suite round-trip a *trained* model through the rust
importer (python export -> rust import -> identical predictions).
"""

import numpy as np

from .model import Architecture


def export_caffe_json(arch: Architecture, params: dict, *, batch_hint: int = 1) -> dict:
    """Serialize a 2-D CNN as a Caffe-vocabulary JSON export document.

    Only the Caffe-expressible subset is supported: conv2d, relu,
    max/avg pool, global avg pool, dense (InnerProduct), dropout, softmax.
    Flatten is implicit in Caffe and therefore dropped.
    """
    if len(arch.input) != 3:
        raise ValueError("caffe export needs [C,H,W] input models")

    def blob(name):
        arr = np.asarray(params[name], dtype=np.float32)
        return {"shape": list(arr.shape), "data": [float(v) for v in arr.reshape(-1)]}

    layers = []
    for l in arch.layers:
        if l.type == "conv2d":
            layers.append(
                {
                    "name": l.name,
                    "type": "Convolution",
                    "convolution_param": {
                        "num_output": l.out_ch,
                        "kernel_size": l.k,
                        "stride": l.stride,
                        "pad": l.pad,
                    },
                    "blobs": [blob(f"{l.name}.w"), blob(f"{l.name}.b")],
                }
            )
        elif l.type == "relu":
            layers.append({"name": l.name, "type": "ReLU"})
        elif l.type in ("max_pool2d", "avg_pool2d"):
            layers.append(
                {
                    "name": l.name,
                    "type": "Pooling",
                    "pooling_param": {
                        "pool": "MAX" if l.type == "max_pool2d" else "AVE",
                        "kernel_size": l.k,
                        "stride": l.stride,
                        "pad": l.pad,
                    },
                }
            )
        elif l.type == "global_avg_pool":
            layers.append(
                {
                    "name": l.name,
                    "type": "Pooling",
                    "pooling_param": {"pool": "AVE", "global_pooling": True},
                }
            )
        elif l.type == "dense":
            layers.append(
                {
                    "name": l.name,
                    "type": "InnerProduct",
                    "inner_product_param": {"num_output": l.out},
                    "blobs": [blob(f"{l.name}.w"), blob(f"{l.name}.b")],
                }
            )
        elif l.type == "dropout":
            layers.append(
                {"name": l.name, "type": "Dropout", "dropout_param": {"dropout_ratio": l.rate}}
            )
        elif l.type == "softmax":
            layers.append({"name": l.name, "type": "Softmax"})
        elif l.type == "flatten":
            continue  # implicit in Caffe's InnerProduct
        else:
            raise ValueError(f"layer type `{l.type}` has no Caffe equivalent")

    return {
        "framework": "caffe",
        "name": arch.name,
        "input_dim": [batch_hint, *arch.input],
        "layers": layers,
    }
