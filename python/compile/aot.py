"""AOT exporter: lower every zoo model to HLO text + weights + manifest.

This is the single place Python runs — `make artifacts` invokes it once;
afterwards the rust `dlk` binary is self-contained.

Per model the exporter emits into ``artifacts/models/<id>/``:

    manifest.json       dlk-model/1 manifest (id, architecture, labels,
                        aot batch list, weights sha256)
    weights.dlkw        DLKW binary weights (trained for lenet/char-cnn,
                        seeded-random for nin — latency-only model)
    model_b<N>.hlo.txt  HLO text of the jitted forward pass at batch N,
                        entry signature (x, param0, param1, ...) with
                        params in Architecture.parameters() order

HLO *text* (not serialized proto) is the interchange format: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dlkw, train
from .model import ZOO, forward

# Batch sizes compiled ahead of time, per model. The coordinator's dynamic
# batcher rounds up to the nearest available size.
AOT_BATCHES = {
    "lenet-mnist": [1, 2, 4, 8, 16, 32],
    "nin-cifar10": [1, 2, 4, 8],
    "char-cnn": [1, 4, 8],
}

LABELS = {
    "lenet-mnist": [str(d) for d in range(10)],
    "nin-cifar10": [
        "h-stripes", "v-stripes", "d-stripes", "a-stripes", "checker",
        "dots", "rings", "h-gradient", "v-gradient", "blobs",
    ],
    "char-cnn": ["sports", "finance", "ml", "cooking"],
}

DESCRIPTIONS = {
    "lenet-mnist": "LeNet digits classifier, trained on procedural glyph data",
    "nin-cifar10": "Network-in-Network CIFAR-10 topology (paper's 20-layer E1 net)",
    "char-cnn": "Zhang&LeCun-style char-level CNN, trained on procedural topics",
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (reassigns 64-bit ids)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def get_params(model_id, arch, quick, cache_dir):
    """Trained params for trainable models (cached), random for NIN."""
    cache = os.path.join(cache_dir, f"{model_id}.npz")
    if os.path.exists(cache):
        print(f"  [{model_id}] using cached trained weights: {cache}")
        loaded = np.load(cache)
        return {k: jnp.asarray(loaded[k]) for k in loaded.files}, None

    if model_id == "lenet-mnist":
        steps = 60 if quick else 400
        print(f"  [{model_id}] training {steps} steps on procedural glyphs ...")
        params, acc, _ = train.train_lenet(steps=steps)
    elif model_id == "char-cnn":
        steps = 40 if quick else 250
        print(f"  [{model_id}] training {steps} steps on procedural topics ...")
        params, acc, _ = train.train_char_cnn(steps=steps)
    else:
        # NIN: the paper's latency model; random (seeded) weights.
        print(f"  [{model_id}] seeded-random weights (latency-only model)")
        return arch.init_params(seed=42), None

    np.savez(cache, **{k: np.asarray(v) for k, v in params.items()})
    with open(os.path.join(cache_dir, f"{model_id}.acc"), "w") as f:
        f.write(f"{acc:.4f}\n")
    return params, acc


def export_model(model_id, out_dir, quick):
    arch = ZOO[model_id]()
    model_dir = os.path.join(out_dir, "models", model_id)
    os.makedirs(model_dir, exist_ok=True)
    cache_dir = os.path.join(out_dir, "trained")
    os.makedirs(cache_dir, exist_ok=True)

    params, acc = get_params(model_id, arch, quick, cache_dir)
    param_order = [name for name, _ in arch.parameters()]
    assert set(param_order) == set(params), (
        f"{model_id}: params mismatch {sorted(params)} vs {sorted(param_order)}"
    )

    # 1. Weights.
    weights_bytes = dlkw.write_dlkw({k: np.asarray(v) for k, v in params.items()})
    weights_path = os.path.join(model_dir, "weights.dlkw")
    with open(weights_path, "wb") as f:
        f.write(weights_bytes)
    sha = hashlib.sha256(weights_bytes).hexdigest()

    # 2. HLO per batch size.
    batches = AOT_BATCHES[model_id]
    if quick:
        batches = batches[:2]

    def fn(x, *flat_params):
        p = dict(zip(param_order, flat_params))
        return (forward(arch, p, x, use_pallas=True),)

    for batch in batches:
        x_spec = jax.ShapeDtypeStruct((batch, *arch.input), jnp.float32)
        p_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in param_order]
        print(f"  [{model_id}] lowering batch={batch} ...")
        lowered = jax.jit(fn).lower(x_spec, *p_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(model_dir, f"model_b{batch}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  [{model_id}]   wrote {path} ({len(text)} chars)")

    # 3. Manifest.
    manifest = {
        "format": "dlk-model/1",
        "id": model_id,
        "version": 1,
        "source": "deeplearningkit",
        "description": DESCRIPTIONS[model_id]
        + (f" (held-out accuracy {acc:.3f})" if acc is not None else ""),
        "architecture": arch.to_json(),
        "labels": LABELS[model_id],
        "aot_batches": batches,
        "weights_sha256": sha,
    }
    with open(os.path.join(model_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"  [{model_id}] manifest written (weights sha256 {sha[:12]}...)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--quick", action="store_true", help="fewer train steps / batch sizes")
    ap.add_argument("--models", default=",".join(ZOO), help="comma-separated model ids")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    for model_id in args.models.split(","):
        if model_id not in ZOO:
            sys.exit(f"unknown model id `{model_id}` (have: {', '.join(ZOO)})")
        print(f"[aot] exporting {model_id}")
        export_model(model_id, out_dir, args.quick)
    # Stamp for make's freshness check.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"[aot] artifacts complete in {out_dir}")


if __name__ == "__main__":
    main()
