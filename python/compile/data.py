"""Procedural datasets (offline substitutes for MNIST / CIFAR-10 / text).

The environment has no network access, so the paper's datasets are
replaced by deterministic procedural generators that exercise the same
code paths (DESIGN.md substitution table):

- `glyphs`: MNIST substitute — 10 digit classes rendered from a 5x7
  bitmap font to 28x28 with random shift/scale/noise. A small CNN
  reaches >95% on it within a few hundred steps, giving the serving
  example a *real trained model* with a real accuracy number.
- `textures`: CIFAR-10 substitute — 10 procedural 32x32x3 texture
  classes (stripe orientations/frequencies, checkers, dots, gradients).
- `chars`: 4-class synthetic character sequences for the char-CNN.

All generators take a seed and are fully reproducible; the rust side
(`rust/src/data/`) implements the same generators (same class
definitions) so rust-served predictions can be scored against labels.
"""

import numpy as np

# 5x7 bitmap font for digits 0-9 (classic LCD-style glyphs).
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def glyphs(n, seed=0):
    """MNIST-like dataset: (images [n,1,28,28] f32 in [0,1], labels [n])."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, 1, 28, 28), dtype=np.float32)
    labels = rng.integers(0, 10, size=n)
    for i, d in enumerate(labels):
        glyph = np.array(
            [[float(ch) for ch in row] for row in _FONT[int(d)]], dtype=np.float32
        )  # 7x5
        # Random integer upscale (2x-3x) and placement.
        sy = rng.integers(2, 4)
        sx = rng.integers(2, 4)
        big = np.kron(glyph, np.ones((sy, sx), dtype=np.float32))
        gh, gw = big.shape
        oy = rng.integers(0, 28 - gh + 1)
        ox = rng.integers(0, 28 - gw + 1)
        img = np.zeros((28, 28), dtype=np.float32)
        img[oy : oy + gh, ox : ox + gw] = big
        # Intensity jitter + noise.
        img *= rng.uniform(0.7, 1.0)
        img += rng.normal(0.0, 0.08, size=img.shape).astype(np.float32)
        images[i, 0] = np.clip(img, 0.0, 1.0)
    return images, labels.astype(np.int32)


def _texture(cls, rng):
    """One 32x32x3 image of texture class `cls` (0..9)."""
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.4, 0.7)
    if cls == 0:  # horizontal stripes
        base = np.sin(freq * yy + phase)
    elif cls == 1:  # vertical stripes
        base = np.sin(freq * xx + phase)
    elif cls == 2:  # diagonal stripes
        base = np.sin(freq * (xx + yy) * 0.7 + phase)
    elif cls == 3:  # anti-diagonal stripes
        base = np.sin(freq * (xx - yy) * 0.7 + phase)
    elif cls == 4:  # checkerboard
        base = np.sign(np.sin(freq * xx + phase) * np.sin(freq * yy + phase))
    elif cls == 5:  # dots (radial bumps on a grid)
        base = np.cos(freq * xx + phase) + np.cos(freq * yy + phase)
    elif cls == 6:  # radial rings
        r = np.sqrt((xx - 16) ** 2 + (yy - 16) ** 2)
        base = np.sin(freq * 2.0 * r + phase)
    elif cls == 7:  # horizontal gradient
        base = (xx / 31.0) * 2 - 1 + 0.3 * np.sin(phase)
    elif cls == 8:  # vertical gradient
        base = (yy / 31.0) * 2 - 1 + 0.3 * np.sin(phase)
    else:  # low-frequency blobs
        base = np.sin(0.2 * xx + phase) * np.sin(0.2 * yy + phase * 0.7)
    img = np.zeros((3, 32, 32), dtype=np.float32)
    tint = rng.uniform(0.5, 1.0, size=3)
    for ch in range(3):
        img[ch] = base * tint[ch]
    img += rng.normal(0, 0.15, size=img.shape).astype(np.float32)
    return np.clip(img * 0.5 + 0.5, 0, 1)


def textures(n, seed=0):
    """CIFAR-like dataset: (images [n,3,32,32] f32 in [0,1], labels [n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    images = np.stack([_texture(int(c), rng) for c in labels])
    return images.astype(np.float32), labels.astype(np.int32)


# 4 "topics" with characteristic vocabulary for the char-CNN.
_TOPIC_WORDS = [
    ["ball", "goal", "team", "score", "match", "league", "coach"],
    ["stock", "market", "price", "trade", "profit", "bank", "share"],
    ["neuron", "tensor", "model", "train", "learn", "layer", "grad"],
    ["pasta", "sauce", "oven", "spice", "flour", "butter", "salt"],
]
ALPHABET = "abcdefghijklmnopqrstuvwxyz0123456789 .,;:!?'\"()-"
ALPHABET_SIZE = 64  # one-hot rows (padded beyond len(ALPHABET))
DOC_LEN = 256


def chars(n, seed=0):
    """Char-CNN dataset: (one-hot [n,64,256] f32, labels [n] in 0..3)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, size=n)
    docs = np.zeros((n, ALPHABET_SIZE, DOC_LEN), dtype=np.float32)
    idx = {ch: i for i, ch in enumerate(ALPHABET)}
    for i, c in enumerate(labels):
        words = []
        while sum(len(w) + 1 for w in words) < DOC_LEN:
            if rng.uniform() < 0.7:
                words.append(str(rng.choice(_TOPIC_WORDS[int(c)])))
            else:  # filler noise words
                length = rng.integers(2, 7)
                words.append("".join(rng.choice(list(ALPHABET[:26]), size=length)))
        text = " ".join(words)[:DOC_LEN]
        for pos, ch in enumerate(text):
            j = idx.get(ch)
            if j is not None:
                docs[i, j, pos] = 1.0
    return docs, labels.astype(np.int32)
