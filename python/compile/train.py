"""Build-time trainer (hand-rolled Adam; no optax offline).

Trains the small zoo models on the procedural datasets so the serving
examples run a *real trained model* with a real accuracy number — the
paper's premise is exactly this asymmetry: training happens elsewhere
("piles of wood of energy"), the device only runs inference ("less energy
than lighting a match").

Entry points:
    train_lenet(steps=...)    -> params, accuracy   (glyph digits)
    train_char_cnn(steps=...) -> params, accuracy   (topic chars)

Training uses the jnp forward path (`use_pallas=False`): interpret-mode
Pallas is numerically identical but orders of magnitude slower, and L1
kernels are validated separately by the pytest suite.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import Architecture, char_cnn, lenet, logits_forward


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new_params = {
        k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params
    }
    return new_params, {"m": m, "v": v, "t": t}


def _train(
    arch: Architecture,
    gen,
    *,
    steps=300,
    batch=64,
    lr=1e-3,
    seed=0,
    eval_n=512,
    log_every=50,
    verbose=True,
):
    """Generic training loop. `gen(n, seed)` yields (x, labels)."""
    params = arch.init_params(seed)

    @jax.jit
    def loss_fn(params, x, y):
        return cross_entropy(logits_forward(arch, params, x), y)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    state = adam_init(params)
    losses = []
    for step in range(steps):
        x, y = gen(batch, seed=seed * 100003 + step + 1)
        loss, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
        params, state = adam_update(params, grads, state, lr=lr)
        losses.append(float(loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"  step {step:4d}  loss {float(loss):.4f}")

    # Held-out accuracy.
    xe, ye = gen(eval_n, seed=987654321 + seed)
    logits = jax.jit(functools.partial(logits_forward, arch))(params, jnp.asarray(xe))
    acc = float(np.mean(np.argmax(np.asarray(logits), axis=-1) == ye))
    if verbose:
        print(f"  held-out accuracy: {acc:.3f}")
    return params, acc, losses


def train_lenet(steps=300, batch=64, seed=0, verbose=True):
    """Train LeNet on the glyph digits. Returns (params, accuracy, losses)."""
    return _train(lenet(), data.glyphs, steps=steps, batch=batch, seed=seed, verbose=verbose)


def train_char_cnn(steps=200, batch=32, seed=0, verbose=True):
    """Train the char-CNN on the topic corpus."""
    return _train(
        char_cnn(), data.chars, steps=steps, batch=batch, lr=5e-4, seed=seed, verbose=verbose
    )
