#!/usr/bin/env python3
"""Gate persisted bench results against committed headline baselines.

Every `cargo bench --bench fig_*` invocation that measures a headline
number persists a machine-readable `BENCH_E<N>.json` into the working
directory (see `rust/src/bench/mod.rs::persist`). This script compares
those artifacts against `python/bench_baselines.json` and fails (exit 1)
if any headline metric regresses by more than the allowed tolerance
(default 20%) — the CI bench matrix runs it after each experiment.

Baselines are deliberately *dimensionless* (speedups and ratios, never
raw microseconds): absolute latencies swing wildly across runner
hardware, but "int8 beats f32" and "autoscale beats static x1" are
machine-shape claims that should hold anywhere the experiment's core
gate passes. Baseline values are conservative floors, not best observed
results.

Usage:
    python3 python/bench_check.py                 # scan CWD for BENCH_*.json
    python3 python/bench_check.py BENCH_E17.json  # check specific artifacts
    python3 python/bench_check.py --update        # rewrite baselines from artifacts

Semantics:
  - an artifact with no baseline entry is reported and skipped (new
    experiments land before their first committed baseline);
  - a baseline entry with no artifact present is skipped silently (the
    CI matrix runs one bench per job, so each job sees only its own
    artifact);
  - a metric path that no longer resolves inside the artifact is a hard
    failure (schema drift must update the baseline, not dodge it).

The only metric-path syntax needed by the current experiments:
  dotted field access (`large_conv.f32_speedup`), integer array index
  (`sweep[3]`, negatives allowed), and `[max]` / `[min]` reductions over
  an array of objects (`sweep[max].speedup_vs_depth1` = best entry).
"""

import argparse
import glob
import json
import os
import re
import sys

DEFAULT_BASELINES = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_baselines.json")
DEFAULT_TOLERANCE = 0.20

_TOKEN = re.compile(r"([A-Za-z0-9_]+)((?:\[(?:-?\d+|max|min)\])*)")


def resolve(doc, path):
    """Resolve a metric path against a parsed artifact.

    Returns the numeric value, or raises KeyError with a readable
    message naming the segment that failed.
    """
    value = value_at(doc, path.split("."), path)
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        raise KeyError("path `%s` resolved to non-numeric %r" % (path, value))
    return float(value)


def value_at(value, segments, full_path):
    if not segments:
        return value
    seg, rest = segments[0], segments[1:]
    m = _TOKEN.fullmatch(seg)
    if not m:
        raise KeyError("malformed path segment `%s` in `%s`" % (seg, full_path))
    name, indexes = m.group(1), re.findall(r"\[(-?\d+|max|min)\]", m.group(2))
    if not isinstance(value, dict) or name not in value:
        raise KeyError("missing field `%s` in `%s`" % (name, full_path))
    value = value[name]
    for idx in indexes:
        if not isinstance(value, list) or not value:
            raise KeyError("`%s` is not a non-empty array in `%s`" % (name, full_path))
        if idx in ("max", "min"):
            # Reduce over the remaining path applied to each element.
            candidates = [value_at(elem, rest, full_path) for elem in value]
            numeric = [c for c in candidates if isinstance(c, (int, float)) and not isinstance(c, bool)]
            if not numeric:
                raise KeyError("`[%s]` found no numeric values for `%s`" % (idx, full_path))
            return max(numeric) if idx == "max" else min(numeric)
        value = value[int(idx)]
    return value_at(value, rest, full_path)


def check_artifact(path, baselines, tolerance):
    """Returns (experiment_id, failures, notes, measured) for one artifact."""
    with open(path) as fh:
        doc = json.load(fh)
    exp = doc.get("experiment")
    failures, notes, measured = [], [], {}
    if not exp:
        return None, ["%s: artifact has no `experiment` field" % path], notes, measured
    entry = baselines.get(exp)
    if entry is None:
        notes.append("%s (%s): no committed baseline — skipping (add one via --update)" % (exp, path))
        return exp, failures, notes, measured
    for metric in entry.get("metrics", []):
        mpath, base = metric["path"], float(metric["baseline"])
        direction = metric.get("direction", "higher")
        try:
            value = resolve(doc, mpath)
        except KeyError as e:
            failures.append("%s %s: %s (schema drift? update the baseline)" % (exp, mpath, e.args[0]))
            continue
        measured[mpath] = value
        if direction == "higher":
            floor = base * (1.0 - tolerance)
            ok, bound = value >= floor, ">= %.4g" % floor
        else:
            ceil = base * (1.0 + tolerance)
            ok, bound = value <= ceil, "<= %.4g" % ceil
        verdict = "ok" if ok else "REGRESSED"
        line = "%s %s = %.4g (baseline %.4g, need %s) %s" % (exp, mpath, value, base, bound, verdict)
        if ok:
            notes.append(line)
        else:
            failures.append(line)
    return exp, failures, notes, measured


def update_baselines(artifacts, baselines, baselines_path, tolerance):
    """Refresh each committed baseline metric from the measured artifacts.

    Only overwrites values for experiments whose artifact is present;
    paths that fail to resolve keep their old value and are reported.
    """
    touched = 0
    for path in artifacts:
        with open(path) as fh:
            doc = json.load(fh)
        exp = doc.get("experiment")
        entry = baselines.get(exp)
        if not exp or entry is None:
            print("update: %s has no baseline entry; add it to %s by hand first" % (path, baselines_path))
            continue
        for metric in entry.get("metrics", []):
            try:
                value = resolve(doc, metric["path"])
            except KeyError as e:
                print("update: keeping %s %s (%s)" % (exp, metric["path"], e.args[0]))
                continue
            metric["baseline"] = round(value, 6)
            touched += 1
    with open(baselines_path, "w") as fh:
        json.dump(baselines, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print("update: wrote %d metric value(s) to %s (tolerance stays %.0f%%)" % (touched, baselines_path, tolerance * 100))


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="*", help="BENCH_*.json files (default: glob the CWD)")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES, help="committed baseline file")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE, help="allowed relative regression (default 0.20)")
    ap.add_argument("--update", action="store_true", help="rewrite baselines from the artifacts instead of checking")
    args = ap.parse_args(argv)

    artifacts = args.artifacts or sorted(glob.glob("BENCH_*.json"))
    if not artifacts:
        print("bench-check: no BENCH_*.json artifacts found in %s — nothing to gate" % os.getcwd())
        return 0
    with open(args.baselines) as fh:
        baselines = json.load(fh)

    if args.update:
        update_baselines(artifacts, baselines, args.baselines, args.tolerance)
        return 0

    all_failures = []
    for path in artifacts:
        exp, failures, notes, _ = check_artifact(path, baselines, args.tolerance)
        for n in notes:
            print("bench-check: %s" % n)
        for f in failures:
            print("bench-check: %s" % f)
        all_failures.extend(failures)
    if all_failures:
        print("bench-check: FAILED — %d headline metric(s) regressed past %.0f%%" % (len(all_failures), args.tolerance * 100))
        return 1
    print("bench-check: all headline metrics within %.0f%% of committed baselines" % (args.tolerance * 100))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
