//! App Store for Deep Learning Models — the full §2 story in one run:
//!
//! 1. Import a (synthetic) Caffe JSON export with the §3 importer.
//! 2. Compress it with the Deep-Compression pipeline (§2's 240 MB → 6.9 MB
//!    technique).
//! 3. Publish both zoo models and the import into a local registry.
//! 4. "Device side": fetch over a simulated LTE link, verify integrity,
//!    then rapid-switch between models through the byte-budgeted cache
//!    while the meta-model selector picks which model a context needs.
//!
//! Run with: `cargo run --release --example app_store_demo`

use deeplearningkit::cache::{ModelCache, PolicyKind};
use deeplearningkit::compression::{compress_model, StagePlan};
use deeplearningkit::metrics::{fmt_bytes, Table};
use deeplearningkit::runtime::Engine;
use deeplearningkit::selector::{Candidate, Context, LocationKind, MetaModel};
use deeplearningkit::store::{Package, Registry, SimulatedNetwork};
use deeplearningkit::{artifacts_dir, data, importer, model, store, testutil};
use std::collections::BTreeMap;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    println!("=== App Store for Deep Learning Models (paper §2) ===\n");

    // ---- 1. Import a Caffe export (the §3 importer) ----------------------
    let caffe_json = synthetic_caffe_export();
    let imported = importer::import_auto(&caffe_json)?;
    println!(
        "[import] Caffe export `{}` -> {} layers, {} params",
        imported.manifest.id,
        imported.manifest.arch.layers.len(),
        imported.manifest.arch.param_count()?
    );

    // ---- 2. Compress (Deep Compression) ----------------------------------
    let (_, report) = compress_model(&imported.weights, StagePlan::default())?;
    println!(
        "[compress] {} -> {} ({:.1}x, sparsity {:.0}%)",
        fmt_bytes(report.sizes.original as u64),
        fmt_bytes(report.sizes.after_huffman as u64),
        report.ratio,
        report.sparsity * 100.0
    );

    // ---- 3. Publish into the store ---------------------------------------
    let registry_dir = testutil::tempdir("appstore-registry");
    let registry = Registry::open(&registry_dir)?;
    for id in ["lenet-mnist", "char-cnn"] {
        let pkg = Package::from_model_dir(&artifacts_dir().join("models").join(id))?;
        let p = registry.publish(&pkg)?;
        println!("[publish] `{}` v{} ({})", p.id, p.version, fmt_bytes(p.package_bytes as u64));
    }
    // Publish the freshly imported model too.
    let import_dir = testutil::tempdir("appstore-import");
    let files = model::ModelFiles::new(&import_dir);
    let weight_bytes = imported.weights.to_bytes();
    std::fs::write(files.weights(), &weight_bytes)?;
    let mut manifest = imported.manifest;
    manifest.weights_sha256 = Some(store::sha256_hex(&weight_bytes));
    manifest.save(&files.manifest())?;
    let p = registry.publish(&Package::from_model_dir(&import_dir)?)?;
    println!("[publish] `{}` v{} (from importer)", p.id, p.version);

    // ---- 4. Device side: fetch + cache + selector ------------------------
    let mut net = SimulatedNetwork::lte();
    let device_store = testutil::tempdir("appstore-device");
    let mut fetched: BTreeMap<String, std::path::PathBuf> = BTreeMap::new();
    for id in ["lenet-mnist", "char-cnn"] {
        let dest = device_store.join(id);
        let stats = registry.fetch_to(id, &mut net, &dest)?;
        println!(
            "[fetch] `{id}`: {} over simulated LTE in {:.2} s (modeled)",
            fmt_bytes(stats.bytes as u64),
            stats.modeled.as_secs_f64()
        );
        fetched.insert(id.to_string(), dest);
    }

    // Rapid model switching through the byte-budgeted cache (paper: "very
    // rapid load them from SSD into GPU accessible RAM").
    let engine = Engine::start()?;
    let mut cache = ModelCache::new(engine, 4_000_000, PolicyKind::Lru);
    for (id, dir) in &fetched {
        cache.register(id, dir);
    }

    let mut table = Table::new("model switching through the cache", &["step", "model", "hit", "latency"]);
    let digit = data::glyphs(1, 1).inputs;
    let text = data::chars(1, 1).inputs;
    for (step, id) in ["lenet-mnist", "char-cnn", "lenet-mnist", "char-cnn"].iter().enumerate() {
        let input = if id.contains("char") { text.clone() } else { digit.clone() };
        let (_, access) = cache.infer(id, input)?;
        table.row(&[
            format!("{step}"),
            id.to_string(),
            format!("{}", access.hit),
            if access.hit {
                "resident".to_string()
            } else {
                format!("{:.1} ms load", access.load_time.as_secs_f64() * 1000.0)
            },
        ]);
    }
    table.print();
    let cs = cache.stats();
    println!(
        "[cache] hits {} misses {} evictions {} (budget {})",
        cs.hits,
        cs.misses,
        cs.evictions,
        fmt_bytes(4_000_000)
    );

    // Meta-model model selection (paper: location/time/history -> model).
    let meta = MetaModel::default();
    let candidates = vec![
        Candidate {
            id: "lenet-mnist".into(),
            location_affinity: BTreeMap::from([(LocationKind::Office, 0.9)]),
            peak_hours: vec![10, 15],
            infer_latency: Duration::from_millis(5),
            load_latency: Duration::from_millis(40),
            resident: cache.is_resident("lenet-mnist"),
        },
        Candidate {
            id: "char-cnn".into(),
            location_affinity: BTreeMap::from([(LocationKind::Home, 0.8)]),
            peak_hours: vec![20],
            infer_latency: Duration::from_millis(8),
            load_latency: Duration::from_millis(60),
            resident: cache.is_resident("char-cnn"),
        },
    ];
    for (loc, hour) in [(LocationKind::Office, 10u8), (LocationKind::Home, 20u8)] {
        let ctx = Context { location: loc, hour, ..Default::default() };
        let choice = meta.select(&ctx, &candidates).expect("a model fits the budget");
        println!(
            "[selector] context ({loc:?}, {hour}:00) -> `{}` (score {:.2}, expected {:.0} ms)",
            choice.id,
            choice.score,
            choice.expected_latency.as_secs_f64() * 1000.0
        );
    }

    println!("\napp_store_demo OK");
    Ok(())
}

/// A small but legitimate Caffe-style JSON export, generated in-process
/// (stands in for a real `caffe_export.py` dump; same schema).
fn synthetic_caffe_export() -> deeplearningkit::json::Value {
    use deeplearningkit::json::Value;
    use deeplearningkit::testutil::XorShiftRng;
    let mut rng = XorShiftRng::new(4242);
    let blob = |dims: &[usize], rng: &mut XorShiftRng| {
        let n: usize = dims.iter().product();
        Value::obj(&[
            ("shape", Value::Array(dims.iter().map(|&d| d.into()).collect())),
            ("data", Value::Array((0..n).map(|_| (rng.normal() as f64 * 0.08).into()).collect())),
        ])
    };
    let layers = vec![
        Value::obj(&[
            ("name", "conv1".into()),
            ("type", "Convolution".into()),
            (
                "convolution_param",
                Value::obj(&[
                    ("num_output", 8usize.into()),
                    ("kernel_size", 5usize.into()),
                    ("stride", 1usize.into()),
                    ("pad", 2usize.into()),
                ]),
            ),
            ("blobs", Value::Array(vec![blob(&[8, 3, 5, 5], &mut rng), blob(&[8], &mut rng)])),
        ]),
        Value::obj(&[("name", "relu1".into()), ("type", "ReLU".into())]),
        Value::obj(&[
            ("name", "pool1".into()),
            ("type", "Pooling".into()),
            (
                "pooling_param",
                Value::obj(&[
                    ("pool", "MAX".into()),
                    ("kernel_size", 2usize.into()),
                    ("stride", 2usize.into()),
                ]),
            ),
        ]),
        Value::obj(&[
            ("name", "ip1".into()),
            ("type", "InnerProduct".into()),
            ("inner_product_param", Value::obj(&[("num_output", 10usize.into())])),
            (
                "blobs",
                Value::Array(vec![blob(&[10, 8 * 16 * 16], &mut rng), blob(&[10], &mut rng)]),
            ),
        ]),
        Value::obj(&[("name", "prob".into()), ("type", "Softmax".into())]),
    ];
    Value::obj(&[
        ("framework", "caffe".into()),
        ("name", "demo_cifar_small".into()),
        (
            "input_dim",
            Value::Array(vec![1usize.into(), 3usize.into(), 32usize.into(), 32usize.into()]),
        ),
        ("layers", Value::Array(layers)),
    ])
}
