//! Internal profiling helper (not part of the public example set): raw
//! engine latency per batch size — used by the §Perf iteration log.
use deeplearningkit::runtime::Engine;
use deeplearningkit::{artifacts_dir, data};
use std::time::Instant;
fn main() {
    let engine = Engine::start().unwrap();
    engine.load(artifacts_dir().join("models").join("lenet-mnist")).unwrap();
    for &n in &[1usize, 8, 32] {
        let batch = data::glyphs(n, 1);
        for _ in 0..3 { engine.infer("lenet-mnist", batch.inputs.clone()).unwrap(); }
        let t0 = Instant::now();
        let iters = 20;
        for _ in 0..iters { engine.infer("lenet-mnist", batch.inputs.clone()).unwrap(); }
        let us = t0.elapsed().as_secs_f64()*1e6/iters as f64;
        println!("lenet batch {n}: {:.0} us/exec, {:.0} us/item", us, us/n as f64);
    }
    engine.load(artifacts_dir().join("models").join("nin-cifar10")).unwrap();
    let batch = data::textures(1, 1);
    engine.infer("nin-cifar10", batch.inputs.clone()).unwrap();
    let t0 = Instant::now();
    for _ in 0..5 { engine.infer("nin-cifar10", batch.inputs.clone()).unwrap(); }
    println!("nin batch 1: {:.0} us/exec", t0.elapsed().as_secs_f64()*1e6/5.0);
    engine.shutdown();
}
