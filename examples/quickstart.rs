//! Quickstart: load a pre-trained model from the artifacts directory and
//! classify a handful of generated digit images — the paper's core
//! use-case ("using pre-trained deep learning models on-device") in ~30
//! lines of user code.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use deeplearningkit::runtime::Engine;
use deeplearningkit::{artifacts_dir, data, model};

fn main() -> anyhow::Result<()> {
    // 1. Start the inference engine (PJRT CPU client on its own thread —
    //    the analog of MTLCreateSystemDefaultDevice + command queue).
    let engine = Engine::start()?;

    // 2. Load a pre-trained model (manifest + weights + AOT-compiled HLO).
    let dir = artifacts_dir().join("models").join("lenet-mnist");
    let info = engine.load(&dir)?;
    println!(
        "loaded `{}`: {} classes, AOT batch sizes {:?}, load took {:.1} ms",
        info.id,
        info.classes,
        info.batches,
        info.load_micros as f64 / 1000.0
    );

    // 3. Generate a batch of labeled digit images and classify them.
    let manifest = model::Manifest::load(&dir.join("manifest.json"))?;
    let batch = data::glyphs(8, 2026);
    let probs = engine.infer(&info.id, batch.inputs.clone())?;
    let preds = probs.argmax_rows();

    let mut correct = 0;
    for (i, (&p, &label)) in preds.iter().zip(&batch.labels).enumerate() {
        let confidence = probs.data()[i * info.classes + p];
        let ok = p == label;
        correct += ok as usize;
        println!(
            "image {i}: predicted `{}` (p={confidence:.3}) actual `{}` {}",
            manifest.labels[p],
            manifest.labels[label],
            if ok { "✓" } else { "✗" }
        );
    }
    println!("accuracy: {correct}/8");
    engine.shutdown();
    Ok(())
}
