//! End-to-end serving driver (the repository's headline validation run,
//! recorded in EXPERIMENTS.md).
//!
//! Loads the *trained* LeNet digits model through the full stack —
//! Pallas-kernel HLO → PJRT engine → dynamic batcher → coordinator — and
//! serves a few thousand classification requests from concurrent client
//! threads, reporting latency percentiles, throughput, batching behaviour,
//! SLO attainment against the paper's 100 ms Nielsen bar, and measured
//! accuracy on held-out generated data.
//!
//! Run with: `cargo run --release --example serving_e2e`
//! Flags: --requests N --concurrency N --max-batch N --max-delay-ms N

use deeplearningkit::cli::Command;
use deeplearningkit::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use deeplearningkit::metrics::Table;
use deeplearningkit::runtime::Engine;
use deeplearningkit::tensor::{Shape, Tensor};
use deeplearningkit::{artifacts_dir, data};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("serving_e2e", "end-to-end serving driver")
        .flag("requests", "total requests", Some("2048"))
        .flag("concurrency", "client threads", Some("8"))
        .flag("max-batch", "batcher max batch", Some("8"))
        .flag("max-delay-ms", "batcher flush deadline ms", Some("2"));
    let a = cmd.parse(&argv)?;
    let requests = a.get_usize("requests", 2048)?;
    let concurrency = a.get_usize("concurrency", 8)?.max(1);
    let max_batch = a.get_usize("max-batch", 8)?;
    let max_delay = Duration::from_millis(a.get_usize("max-delay-ms", 2)? as u64);

    println!("=== DeepLearningKit serving e2e ===");
    let engine = Engine::start()?;
    let mut coord = Coordinator::new(
        engine,
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch, max_delay, queue_cap: 8192 },
        },
    );
    let t_load = Instant::now();
    let info = coord.serve_model(artifacts_dir().join("models").join("lenet-mnist"))?;
    println!(
        "model `{}` loaded+compiled in {:.1} ms ({} AOT batch sizes, {:.1} MB weights)",
        info.id,
        t_load.elapsed().as_secs_f64() * 1000.0,
        info.batches.len(),
        info.weight_bytes as f64 / 1e6
    );

    let coord = Arc::new(coord);
    let correct = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let per_thread = (requests / concurrency).max(1);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..concurrency {
            let coord = coord.clone();
            let correct = correct.clone();
            let failed = failed.clone();
            scope.spawn(move || {
                let batch = data::glyphs(per_thread, 40_000 + t as u64);
                for i in 0..per_thread {
                    let input = Tensor::new(
                        Shape::new(&[1usize, 28, 28]),
                        batch.inputs.data()[i * 784..(i + 1) * 784].to_vec(),
                    )
                    .unwrap();
                    match coord.infer("lenet-mnist", input) {
                        Ok(r) => {
                            if r.predicted == batch.labels[i] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();

    let stats = coord.stats();
    let served = requests as u64 - failed.load(Ordering::Relaxed);
    let acc = correct.load(Ordering::Relaxed) as f64 / served.max(1) as f64;

    let mut table = Table::new(
        "serving results (trained LeNet, full three-layer stack)",
        &["metric", "value"],
    );
    table.row(&["requests".into(), format!("{requests}")]);
    table.row(&["client threads".into(), format!("{concurrency}")]);
    table.row(&["wall time".into(), format!("{:.2} s", wall.as_secs_f64())]);
    table.row(&[
        "throughput".into(),
        format!("{:.0} req/s", served as f64 / wall.as_secs_f64()),
    ]);
    table.row(&["p50 latency".into(), format!("{:.2} ms", stats.p50_us as f64 / 1000.0)]);
    table.row(&["p95 latency".into(), format!("{:.2} ms", stats.p95_us as f64 / 1000.0)]);
    table.row(&["p99 latency".into(), format!("{:.2} ms", stats.p99_us as f64 / 1000.0)]);
    table.row(&["mean batch size".into(), format!("{:.2}", stats.mean_batch_size)]);
    table.row(&["batches executed".into(), format!("{}", stats.batches)]);
    table.row(&[
        "SLO attainment (100 ms)".into(),
        format!("{:.2}%", stats.slo_attainment * 100.0),
    ]);
    table.row(&["held-out accuracy".into(), format!("{:.4}", acc)]);
    table.row(&["failed requests".into(), format!("{}", failed.load(Ordering::Relaxed))]);
    table.print();

    anyhow::ensure!(acc > 0.9, "accuracy regression: {acc}");
    anyhow::ensure!(
        stats.slo_attainment > 0.9,
        "SLO regression: {:.1}%",
        stats.slo_attainment * 100.0
    );
    println!("serving_e2e OK");
    Ok(())
}
