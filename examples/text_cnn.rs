//! Text understanding with 1-D convolutions (paper roadmap item 9: adapt
//! Zhang & LeCun's "Text Understanding from Scratch" encoding + 1-D
//! operators).
//!
//! Serves the trained char-CNN through the full stack and classifies
//! synthetic documents into the four topic classes, showing the same API
//! works beyond image models.
//!
//! Run with: `cargo run --release --example text_cnn`

use deeplearningkit::runtime::Engine;
use deeplearningkit::{artifacts_dir, data, model};

fn main() -> anyhow::Result<()> {
    let engine = Engine::start()?;
    let dir = artifacts_dir().join("models").join("char-cnn");
    let info = engine.load(&dir)?;
    let manifest = model::Manifest::load(&dir.join("manifest.json"))?;
    println!(
        "loaded `{}`: classes {:?}, input one-hot [{} x {}]",
        info.id,
        manifest.labels,
        data::CHAR_ALPHABET_SIZE,
        data::CHAR_DOC_LEN
    );

    let batch = data::chars(8, 314);
    let probs = engine.infer(&info.id, batch.inputs.clone())?;
    let preds = probs.argmax_rows();

    let mut correct = 0;
    for (i, (&p, &label)) in preds.iter().zip(&batch.labels).enumerate() {
        let ok = p == label;
        correct += ok as usize;
        println!(
            "doc {i}: predicted `{}` (p={:.3}) actual `{}` {}",
            manifest.labels[p],
            probs.data()[i * 4 + p],
            manifest.labels[label],
            if ok { "✓" } else { "✗" }
        );
    }
    println!("topic accuracy: {correct}/8");
    anyhow::ensure!(correct >= 6, "char-cnn accuracy regressed");
    engine.shutdown();
    Ok(())
}
